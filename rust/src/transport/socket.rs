//! The **Unix-domain-socket backend**: ghost deltas and staleness pulls
//! moved as real kernel-socket bytes between per-shard endpoints — the
//! in-process rehearsal of a true multi-process deployment, std-only, no
//! filesystem footprint beyond a per-run temp directory of socket files
//! (removed on drop, so parallel test binaries never collide).
//!
//! # Wire format
//!
//! The raw backend (`"socket"`) ships exactly the parent `transport`
//! module's two frame kinds, byte-for-byte:
//!
//! * **delta frames** (`u32 vertex, u64 version, u32 len, payload`) flow
//!   over one `UnixStream` per ordered shard pair into the destination
//!   endpoint; replicas apply **newest-wins** at [`GhostTransport::drain`]
//!   (`GhostEntry::store_versioned`), so frames reordered across
//!   connections — or re-sent after a reconnect — are harmless;
//! * **pull frames** (`u32 vertex, u64 min_version`, fixed
//!   [`PullRequest::WIRE_LEN`] bytes) cross a dedicated request/reply
//!   socketpair lane per ordered shard pair; the reply is an ordinary
//!   delta frame carrying the owner's current master data.
//!
//! The compressed backend ([`SocketTransport::compressed`], exposed as
//! `"socket-z"`) replaces the delta frame with the shadow-diff frame of
//! [`super::encode_delta`] wrapped in an 8-byte envelope:
//!
//! ```text
//! envelope := u32 src_shard, u32 body_len, body
//! body     := one compressed delta frame (varint header + diff/raw body)
//! reset    := u32 src_shard, u32 0xFFFF_FFFF   (no body)
//! ```
//!
//! The `src` field keys the receiver's per-`(src, vertex)` diff shadows
//! (one inbox mixes every source), and the in-band **reset marker** voids
//! every shadow for its source — the sender emits one after a reconnect
//! and re-ships everything staged since its last complete flush as raw
//! frames, so a dropped connection can never desync the diff shadows.
//! Pull frames stay raw on both variants.
//!
//! # Topology & delivery
//!
//! Each shard binds one endpoint (`shard-<i>.sock`) in a unique temp
//! directory; every other shard connects to it and identifies itself with
//! a 4-byte handshake. **One reader thread serves each endpoint**: it
//! accepts connections (including re-connections), moves received bytes
//! into per-stream staging buffers, and forwards only *complete* frames
//! to the endpoint inbox — a torn write from a dropped connection can
//! never corrupt the frame stream, and the sender's retry after a
//! reconnect lands cleanly. Workers apply inboxed frames on their normal
//! [`GhostTransport::drain`] cadence.
//!
//! # Vectored writes
//!
//! Sends do not hit the kernel one frame at a time: each connection
//! **stages** encoded frames in a queue and flushes them with a single
//! `write_vectored` (writev) syscall once [`STAGE_MAX_BYTES`] /
//! [`STAGE_MAX_FRAMES`] accumulate — or earlier, when the destination
//! drains (senders are in-process, so [`GhostTransport::drain`] first
//! pushes everything still staged toward it), at [`GhostTransport::finalize`],
//! and from inside a backpressured sender's own stall loop (a sender must
//! be able to land the bytes it itself staged, or a tiny send window
//! would deadlock).
//!
//! # Backpressure & reconnect
//!
//! Every connection has a **bounded send window** (default
//! [`DEFAULT_SEND_BUFFER`] bytes of in-flight data, configurable down to
//! bytes for tests): a send that would overflow it blocks — stalling the
//! engine's batcher flush, which is the intended flow control — until the
//! reader lands enough bytes, and each stalled send increments the
//! [`GhostTransport::backpressure_stalls`] counter. A frame larger than
//! the whole window is sent alone once the window is empty, so progress
//! is always possible. Flushes that fail with a broken pipe reconnect to
//! the endpoint (fresh handshake) under **capped exponential backoff** —
//! a deterministic 2, 4, 8, …, 64 ms schedule, each wait counted in
//! [`GhostTransport::reconnect_backoffs`] — and resend every frame staged
//! since the last complete flush (raw mode resends the staged queue
//! verbatim; compressed mode re-encodes it raw behind a shadow-reset
//! marker); exhausting the attempt budget panics with the shard pair in
//! the message, never drops a delta silently. Pull lanes carry read and
//! write timeouts, so a crashed peer surfaces as a counted
//! [`GhostTransport::pull_timeouts`] failure (retried by the engine's
//! scope-admission backoff loop) instead of hanging the admitting worker.
//! [`SocketTransport::sever_delta_connection`] and
//! [`SocketTransport::sever_pull_lane`] let fault tests trip both paths
//! on demand.
//!
//! # Pull pipelining
//!
//! [`GhostTransport::pull_many`] batches a scope's stale-ghost refreshes:
//! all request frames bound for one owner cross the lane in a single
//! write before the first reply is served, so N staleness pulls cost one
//! lane acquisition and one request syscall instead of N lock-step
//! round-trips ([`SocketTransport::pulls_pipelined`] counts them).
//!
//! # Resident (multi-process) mode
//!
//! Everything above describes the **in-process** topology: one transport
//! instance owns every endpoint and the requester thread plays both ends
//! of each pull lane. [`SocketTransport::resident`] is the real thing —
//! one transport instance per OS process, running exactly one shard, all
//! instances rendezvousing through a shared directory
//! ([`SocketTransport::with_rendezvous_dir`] is the same naming fix for
//! the in-process case). A resident instance:
//!
//! * binds its delta endpoint `shard-<r>.sock` **and** its pull-service
//!   endpoint `pull-<r>.sock` before connecting out to any peer (with
//!   bounded retry), so fleet launch order cannot deadlock;
//! * ships **raw frames only** (the shadow-diff variant stays
//!   in-process) and skips send-window accounting — the decrementing
//!   reader lives in the peer's process, so flow control falls back to
//!   the kernel's socket buffers;
//! * writes an eager 16-byte **version-announce** frame per delta send
//!   (`u32 vertex, u64 version, u32` [`ANNOUNCE_LEN`], no payload)
//!   straight to the stream, decoupling the version signal from batched
//!   data delivery: the peer's reader records announced versions on a
//!   per-vertex **version board**, which
//!   [`GhostTransport::known_master_version`] folds into the engine's
//!   staleness admission — the only way one process can observe that a
//!   remote master moved;
//! * answers peer pulls from an **owner-side pull service thread**
//!   ([`GhostTransport::serve_pulls`]): requesters hold persistent
//!   clients to each owner's service, ship pipelined request waves, and
//!   apply the reply delta frames — no process ever reads another's
//!   master memory. After its engine finishes, the service writes a
//!   `done-<r>` marker in the rendezvous dir and lingers (still
//!   serving) until every peer's marker exists, so a fast shard cannot
//!   strand a slow peer's last admission pulls;
//! * survives a kill -9'd peer: delta flushes toward a dead endpoint
//!   burn a short reconnect budget and then go dark (dropping their
//!   staged frames — recovery is the snapshot-restore restart), and
//!   pull clients fail fast after a few consecutive failures instead of
//!   paying the IO timeout on every admission.

use super::{
    decode_header, decode_payload, encode_delta, put_u32, ByteReader, DrainReceipt, GhostDelta,
    GhostTransport, PullReceipt, PullRequest, SendReceipt, VertexCodec,
};
use crate::graph::{ShardedGraph, VertexId};
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, IoSlice, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Default per-connection bounded send window, in bytes of in-flight
/// (sent but not yet received) data.
pub const DEFAULT_SEND_BUFFER: usize = 1 << 20;

/// Delta frame header size: `u32 vertex + u64 version + u32 payload_len`.
const FRAME_HEADER: usize = 16;

/// Compressed-mode envelope header: `u32 src_shard + u32 body_len`.
const ENVELOPE_HEADER: usize = 8;

/// Sentinel `body_len` marking a shadow-reset envelope (no body): the
/// receiver voids every diff shadow for the envelope's source shard. A
/// real body can never reach this length.
const SHADOW_RESET: u32 = u32::MAX;

/// Flush the staged frame queue to the kernel (one writev) once it holds
/// this many bytes.
const STAGE_MAX_BYTES: usize = 32 << 10;

/// Flush the staged frame queue once it holds this many frames, whatever
/// their byte total — bounds the iovec length handed to `write_vectored`.
const STAGE_MAX_FRAMES: usize = 64;

/// Max pull requests in flight on one lane per pipelined wave: bounds the
/// kernel buffer the batched request write can occupy (the requester
/// thread plays both lane ends, so unread requests sit in the socketpair
/// buffer until phase 2 serves them).
const PULL_WAVE_MAX: usize = 64;

/// Chunk size for the lock-step pull exchange: the requester thread plays
/// both ends of the lane, so no more than this many reply bytes are ever
/// in a kernel buffer — the exchange can never deadlock on buffer space.
const PULL_CHUNK: usize = 16 << 10;

/// How many reconnect attempts a broken-pipe flush gets before giving up
/// and panicking with the shard-pair context.
const RECONNECT_ATTEMPTS_MAX: u32 = 8;

/// Ceiling of the reconnect backoff schedule: waits double per attempt
/// (2, 4, 8, … ms) and cap here. Deterministic — no wall-clock jitter.
const RECONNECT_BACKOFF_CAP_MS: u64 = 64;

/// Read/write timeout on pull-lane sockets: a crashed or severed peer
/// fails the exchange (counted as a pull timeout) instead of hanging the
/// admitting worker indefinitely.
const PULL_IO_TIMEOUT: Duration = Duration::from_millis(500);

/// Upper bound on one send's backpressure stall (64 yields, then 50µs
/// sleeps — roughly one second). Keeps the soft window bound from ever
/// livelocking a sender if reconnect-torn accounting leaks the window
/// shut.
const STALL_ITERS_MAX: u32 = 20_000;

/// Payload-length sentinel marking a **version-announce** frame: a
/// header-only delta frame (`u32 vertex, u64 version, u32 ANNOUNCE_LEN`)
/// a resident sender writes straight to the stream at send time, before
/// the staged data frame ships, so the peer process learns the master
/// moved without waiting on batched data delivery. Announce frames feed
/// the receiver's version board and never reach the inbox. A real
/// payload can never reach this length.
const ANNOUNCE_LEN: u32 = u32::MAX;

/// Rendezvous connect retry budget: a resident child may come up seconds
/// before its peers bind their endpoints, so outward connects retry this
/// many times at [`CONNECT_RETRY_WAIT`] intervals (~10 s total) before
/// failing the constructor.
const CONNECT_RETRIES: u32 = 500;

/// Pause between rendezvous connect attempts.
const CONNECT_RETRY_WAIT: Duration = Duration::from_millis(20);

/// Read/write timeout on resident pull clients: tighter than the
/// in-process lane timeout so a kill -9'd owner costs a surviving
/// requester a fraction of a second per admission, not half of one.
const RESIDENT_PULL_TIMEOUT: Duration = Duration::from_millis(250);

/// Consecutive failures against one owner's pull service before the
/// client is marked dead and later pulls fail fast (counted as pull
/// timeouts) instead of paying the IO timeout every time.
const PULL_CLIENT_FAILS_MAX: u32 = 3;

/// Resident reconnect budget for a delta connection before it is written
/// off as dead and its staged frames dropped: a kill -9'd peer must not
/// panic the survivors (recovery is the snapshot-restore restart, not
/// this connection).
const RESIDENT_RECONNECT_MAX: u32 = 4;

/// How long a finished resident pull service lingers — still serving —
/// for peers that have not yet written their done markers.
const DONE_LINGER: Duration = Duration::from_secs(10);

/// A unique socket directory per transport instance: process id plus an
/// in-process sequence number, so parallel test binaries (and parallel
/// tests within one binary) never collide on socket paths. This is the
/// **in-process fallback** — cross-process topologies must share an
/// explicit rendezvous dir instead ([`SocketTransport::resident`],
/// [`SocketTransport::with_rendezvous_dir`]), because parent and
/// children would compute different pid-based paths.
fn next_socket_dir() -> PathBuf {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("graphlab-sock-{}-{seq}", std::process::id()))
}

/// Pull-service endpoint of `shard` inside a rendezvous/socket dir.
fn pull_endpoint(dir: &Path, shard: usize) -> PathBuf {
    dir.join(format!("pull-{shard}.sock"))
}

/// Done-marker path of `shard` inside a rendezvous dir: written by the
/// shard's pull service once its local engine finished, read by every
/// peer's service to decide when lingering may end.
fn done_marker(dir: &Path, shard: usize) -> PathBuf {
    dir.join(format!("done-{shard}"))
}

/// Connect with bounded retry: rendezvous peers bind their endpoints at
/// their own pace, so the first connects of a fast-launching child race
/// a slow sibling's bind.
fn connect_retry(endpoint: &Path) -> std::io::Result<UnixStream> {
    let mut last = None;
    for _ in 0..CONNECT_RETRIES {
        match UnixStream::connect(endpoint) {
            Ok(stream) => return Ok(stream),
            Err(e) => last = Some(e),
        }
        std::thread::sleep(CONNECT_RETRY_WAIT);
    }
    Err(last.unwrap_or_else(|| {
        std::io::Error::new(ErrorKind::NotFound, "rendezvous peer never bound its endpoint")
    }))
}

/// Write half of one `src -> dst` delta connection, with its staged-frame
/// queue and (compressed mode) the sender-side diff shadows.
struct Connection {
    stream: UnixStream,
    endpoint: PathBuf,
    src: u32,
    compress: bool,
    /// Whole encoded frames (raw delta frames, or compressed envelopes)
    /// staged but not yet handed to the kernel.
    staged: VecDeque<Vec<u8>>,
    staged_bytes: usize,
    /// Compressed mode: payload as of the last frame encoded per vertex —
    /// the diff base the receiver's shadow mirrors.
    shadow: HashMap<VertexId, Vec<u8>>,
    /// Compressed mode: `(vertex, version, payload)` of every frame staged
    /// since the last complete flush — the raw resend set after a
    /// reconnect (cleared once a flush fully lands).
    meta: Vec<(VertexId, u64, Vec<u8>)>,
    /// Resident mode: flush failures toward this peer are survivable —
    /// after [`RESIDENT_RECONNECT_MAX`] reconnect attempts the connection
    /// goes dead and staged frames are dropped, because a kill -9'd peer
    /// must not panic the survivors.
    best_effort: bool,
    /// Set once a best-effort connection exhausts its reconnect budget;
    /// every later stage/flush toward it is a cheap no-op.
    dead: bool,
}

impl Connection {
    fn open(endpoint: &Path, src: u32, compress: bool) -> std::io::Result<Connection> {
        let mut stream = UnixStream::connect(endpoint)?;
        stream.write_all(&src.to_le_bytes())?;
        Ok(Connection {
            stream,
            endpoint: endpoint.to_path_buf(),
            src,
            compress,
            staged: VecDeque::new(),
            staged_bytes: 0,
            shadow: HashMap::new(),
            meta: Vec::new(),
            best_effort: false,
            dead: false,
        })
    }

    /// Rendezvous variant of [`Connection::open`]: bounded-retry connect
    /// (the peer process may not have bound yet), raw frames only, and
    /// best-effort flushes — peers in other processes can die for real.
    fn open_rendezvous(endpoint: &Path, src: u32) -> std::io::Result<Connection> {
        let mut stream = connect_retry(endpoint)?;
        stream.write_all(&src.to_le_bytes())?;
        Ok(Connection {
            stream,
            endpoint: endpoint.to_path_buf(),
            src,
            compress: false,
            staged: VecDeque::new(),
            staged_bytes: 0,
            shadow: HashMap::new(),
            meta: Vec::new(),
            best_effort: true,
            dead: false,
        })
    }

    /// Queue one whole encoded frame for the next flush.
    fn stage(&mut self, frame: Vec<u8>) {
        self.staged_bytes += frame.len();
        self.staged.push_back(frame);
    }

    /// Compressed mode: encode `(vertex, version, payload)` as a diff
    /// against this lane's shadow (raw on first ship), wrap it in the
    /// `u32 src, u32 body_len` envelope, advance the shadow, and stage
    /// it. Returns the staged envelope length.
    fn stage_compressed(&mut self, vertex: VertexId, version: u64, payload: &[u8]) -> usize {
        let mut envelope = Vec::with_capacity(ENVELOPE_HEADER + payload.len() + 21);
        put_u32(&mut envelope, self.src);
        put_u32(&mut envelope, 0); // body_len, patched below
        let shadow = self.shadow.get(&vertex).map(|s| s.as_slice());
        let body_len = encode_delta(vertex, version, payload, shadow, &mut envelope);
        debug_assert!((body_len as u32) < SHADOW_RESET);
        envelope[4..8].copy_from_slice(&(body_len as u32).to_le_bytes());
        self.shadow
            .entry(vertex)
            .and_modify(|p| {
                p.clear();
                p.extend_from_slice(payload);
            })
            .or_insert_with(|| payload.to_vec());
        self.meta.push((vertex, version, payload.to_vec()));
        let n = envelope.len();
        self.stage(envelope);
        n
    }

    /// Hand the whole staged queue to the kernel with as few
    /// `write_vectored` (writev) syscalls as it takes, reconnecting with
    /// capped backoff on a broken pipe. Frames the kernel accepted only
    /// partially stay at the queue front minus the written prefix — the
    /// reader forwards only complete frames, so a torn tail that dies
    /// with a dropped connection is simply resent whole. On return the
    /// queue is empty and (compressed mode) the resend set is cleared.
    fn flush(
        &mut self,
        dst: usize,
        window: &AtomicUsize,
        reconnects: &AtomicU64,
        backoffs: &AtomicU64,
    ) {
        if self.dead {
            self.staged.clear();
            self.staged_bytes = 0;
            self.meta.clear();
            return;
        }
        let mut attempt = 0u32;
        while !self.staged.is_empty() {
            let res = {
                let slices: Vec<IoSlice<'_>> =
                    self.staged.iter().map(|f| IoSlice::new(f.as_slice())).collect();
                self.stream.write_vectored(&slices)
            };
            match res {
                // A zero-length write with frames still staged cannot make
                // progress: treat it like a dead connection.
                Ok(0) => {
                    self.reconnect_and_restage(dst, window, reconnects, backoffs, &mut attempt)
                }
                Ok(n) => {
                    self.staged_bytes -= n;
                    let mut left = n;
                    while left > 0 {
                        let front = self.staged.front_mut().unwrap();
                        if left >= front.len() {
                            left -= front.len();
                            self.staged.pop_front();
                        } else {
                            front.drain(..left);
                            left = 0;
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::BrokenPipe
                            | ErrorKind::ConnectionReset
                            | ErrorKind::ConnectionAborted
                            | ErrorKind::NotConnected
                            | ErrorKind::WriteZero
                    ) =>
                {
                    self.reconnect_and_restage(dst, window, reconnects, backoffs, &mut attempt)
                }
                Err(e) => panic!(
                    "ghost delta flush (shard {} -> {dst}) to {:?} failed: {e}",
                    self.src, self.endpoint
                ),
            }
        }
        self.meta.clear();
    }

    /// Reconnect after a broken-pipe flush (counted, capped-exponential
    /// backoff) and rebuild the staged queue for the fresh connection.
    ///
    /// Raw mode keeps the queue verbatim — raw frames are self-contained
    /// and newest-wins makes duplicates harmless. Compressed mode must
    /// also repair the diff shadows: the receiver may have applied some,
    /// none, or all of the staged diffs before the connection died, so
    /// the resend is one contiguous buffer of a shadow-reset marker
    /// followed by every frame staged since the last complete flush,
    /// re-encoded **raw** — after which both ends' shadows agree again
    /// (exactly the resend set, last write per vertex).
    ///
    /// Each reconnect re-adds the resend bytes to `window`: the reader
    /// decremented every raw byte it received off the old connection
    /// (including torn tails), so without the re-add a resend could drive
    /// the window negative and let `finalize` return with bytes still in
    /// flight. The accounting errs toward a bounded *over*-count per
    /// reconnect; the send path's stall loop is time-bounded for exactly
    /// this reason.
    fn reconnect_and_restage(
        &mut self,
        dst: usize,
        window: &AtomicUsize,
        reconnects: &AtomicU64,
        backoffs: &AtomicU64,
        attempt: &mut u32,
    ) {
        *attempt += 1;
        if self.best_effort && *attempt > RESIDENT_RECONNECT_MAX {
            // The peer process is gone (kill -9 or crash): drop the
            // staged frames and go dead rather than panic the survivor —
            // correctness comes back via snapshot-restore restart.
            self.dead = true;
            self.staged.clear();
            self.staged_bytes = 0;
            self.meta.clear();
            return;
        }
        assert!(
            *attempt <= RECONNECT_ATTEMPTS_MAX,
            "ghost delta flush (shard {src} -> {dst}) to {:?} failed after \
             {RECONNECT_ATTEMPTS_MAX} reconnect attempts with {} staged frames",
            self.endpoint,
            self.staged.len(),
            src = self.src,
        );
        reconnects.fetch_add(1, Ordering::Relaxed);
        backoffs.fetch_add(1, Ordering::Relaxed);
        crate::telemetry::instant(
            crate::telemetry::EventKind::SocketReconnect,
            dst as u64,
            *attempt as u64,
        );
        let wait = (1u64 << *attempt).min(RECONNECT_BACKOFF_CAP_MS);
        std::thread::sleep(Duration::from_millis(wait));
        if let Ok(fresh) = Connection::open(&self.endpoint, self.src, self.compress) {
            self.stream = fresh.stream;
        }
        if self.compress {
            let mut resend = Vec::new();
            put_u32(&mut resend, self.src);
            put_u32(&mut resend, SHADOW_RESET);
            for (vertex, version, payload) in &self.meta {
                let at = resend.len();
                put_u32(&mut resend, self.src);
                put_u32(&mut resend, 0);
                let body_len = encode_delta(*vertex, *version, payload, None, &mut resend);
                resend[at + 4..at + 8].copy_from_slice(&(body_len as u32).to_le_bytes());
            }
            self.shadow.clear();
            for (vertex, _, payload) in &self.meta {
                self.shadow.insert(*vertex, payload.clone());
            }
            window.fetch_add(resend.len(), Ordering::AcqRel);
            self.staged_bytes = resend.len();
            self.staged.clear();
            self.staged.push_back(resend);
        } else {
            window.fetch_add(self.staged_bytes, Ordering::AcqRel);
        }
    }
}

/// The request/reply socketpair lane one ordered shard pair uses for
/// staleness pulls. `near` is the requester's end, `far` the owner's.
struct PullLane {
    near: UnixStream,
    far: UnixStream,
}

/// Resident-mode requester half toward one remote owner's pull service:
/// a persistent stream with bounded IO timeouts, replaced wholesale
/// after any failed exchange (a timed-out exchange can leave half a
/// frame on the stream, so reuse would desync the protocol) and marked
/// dead after [`PULL_CLIENT_FAILS_MAX`] consecutive failures so a
/// kill -9'd owner fails admissions fast instead of stalling each one
/// on the timeout.
struct PullClient {
    stream: Option<UnixStream>,
    endpoint: PathBuf,
    fails: u32,
}

impl PullClient {
    fn dead(&self) -> bool {
        self.fails >= PULL_CLIENT_FAILS_MAX
    }

    /// Record an IO failure: drop the (possibly desynced) stream and try
    /// one fresh connect for the next exchange.
    fn fail_and_reconnect(&mut self) {
        self.fails += 1;
        self.stream = None;
        if self.dead() {
            return;
        }
        if let Ok(stream) = UnixStream::connect(&self.endpoint) {
            if stream.set_read_timeout(Some(RESIDENT_PULL_TIMEOUT)).is_ok()
                && stream.set_write_timeout(Some(RESIDENT_PULL_TIMEOUT)).is_ok()
            {
                self.stream = Some(stream);
            }
        }
    }
}

/// One accepted inbound stream at an endpoint, with its frame-staging
/// buffer (bytes received but not yet forming a complete frame).
struct Rx {
    stream: UnixStream,
    src: usize,
    staging: Vec<u8>,
}

/// Read the 4-byte source-shard handshake a fresh connection leads with.
/// Bounded by a read timeout — the reader thread is shared by the whole
/// endpoint, so a connector that writes nothing must not freeze delta
/// delivery for the shard — and rejects ids outside `0..k` (a stray
/// connector must not index the window table).
fn handshake(mut stream: UnixStream, k: usize) -> Option<Rx> {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut id = [0u8; 4];
    stream.read_exact(&mut id).ok()?;
    let src = u32::from_le_bytes(id) as usize;
    if src >= k {
        return None;
    }
    let _ = stream.set_read_timeout(Some(Duration::from_millis(2)));
    Some(Rx { stream, src, staging: Vec::new() })
}

/// Move every complete frame at the front of `staging` into the endpoint
/// inbox, leaving a partial frame (if any) in place. Raw mode walks delta
/// frames (`len` at bytes 12..16); compressed mode walks envelopes (`len`
/// at bytes 4..8, [`SHADOW_RESET`] marking a body-less reset).
fn forward_frames(staging: &mut Vec<u8>, inbox: &Mutex<Vec<u8>>, compress: bool) {
    let mut end = 0usize;
    if compress {
        while staging.len() - end >= ENVELOPE_HEADER {
            let len = u32::from_le_bytes(staging[end + 4..end + 8].try_into().unwrap());
            let total = if len == SHADOW_RESET {
                ENVELOPE_HEADER
            } else {
                ENVELOPE_HEADER + len as usize
            };
            if staging.len() - end < total {
                break;
            }
            end += total;
        }
    } else {
        while staging.len() - end >= FRAME_HEADER {
            let len =
                u32::from_le_bytes(staging[end + 12..end + 16].try_into().unwrap()) as usize;
            if staging.len() - end < FRAME_HEADER + len {
                break;
            }
            end += FRAME_HEADER + len;
        }
    }
    if end > 0 {
        inbox.lock().unwrap().extend_from_slice(&staging[..end]);
        staging.drain(..end);
    }
}

/// The reader loop serving one shard endpoint (see the module docs): pure
/// byte mover — it never touches graph data, so it can outlive the
/// engine's scoped workers and be joined on transport drop.
fn reader_loop(
    listener: UnixListener,
    dst: usize,
    k: usize,
    inboxes: Arc<Vec<Mutex<Vec<u8>>>>,
    window: Arc<Vec<AtomicUsize>>,
    shutdown: Arc<AtomicBool>,
    compress: bool,
) {
    let _ = listener.set_nonblocking(true);
    let mut streams: Vec<Rx> = Vec::new();
    let mut buf = vec![0u8; 16 << 10];
    loop {
        // Fresh connections (initial set and reconnecting senders alike).
        while let Ok((stream, _)) = listener.accept() {
            if let Some(rx) = handshake(stream, k) {
                streams.push(rx);
            }
        }
        let mut moved = false;
        streams.retain_mut(|rx| match rx.stream.read(&mut buf) {
            // EOF: the sender shut the connection down; any torn frame
            // tail in staging dies with it (the sender resends whole
            // frames on its replacement connection).
            Ok(0) => false,
            Ok(n) => {
                // Land the bytes before shrinking the send window so the
                // window never under-counts what is still invisible to
                // `drain`.
                rx.staging.extend_from_slice(&buf[..n]);
                forward_frames(&mut rx.staging, &inboxes[dst], compress);
                let _ = window[rx.src * k + dst].fetch_update(
                    Ordering::AcqRel,
                    Ordering::Acquire,
                    |w| Some(w.saturating_sub(n)),
                );
                moved = true;
                true
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                true
            }
            Err(_) => false,
        });
        if streams.is_empty() && shutdown.load(Ordering::Acquire) {
            return;
        }
        if !moved {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

/// Resident-mode variant of [`forward_frames`]: walks raw delta frames,
/// records every frame header's `(vertex, version)` on the version board
/// (`fetch_max` — announce/data ordering is free), consumes announce
/// frames (board-only, never forwarded), and moves complete data frames
/// into the inbox.
fn resident_forward_frames(staging: &mut Vec<u8>, inbox: &Mutex<Vec<u8>>, board: &[AtomicU64]) {
    let mut out: Vec<u8> = Vec::new();
    let mut pos = 0usize;
    while staging.len() - pos >= FRAME_HEADER {
        let vertex = u32::from_le_bytes(staging[pos..pos + 4].try_into().unwrap()) as usize;
        let version = u64::from_le_bytes(staging[pos + 4..pos + 12].try_into().unwrap());
        let len = u32::from_le_bytes(staging[pos + 12..pos + 16].try_into().unwrap());
        if len == ANNOUNCE_LEN {
            if let Some(slot) = board.get(vertex) {
                slot.fetch_max(version, Ordering::AcqRel);
            }
            pos += FRAME_HEADER;
            continue;
        }
        let total = FRAME_HEADER + len as usize;
        if staging.len() - pos < total {
            break;
        }
        if let Some(slot) = board.get(vertex) {
            slot.fetch_max(version, Ordering::AcqRel);
        }
        out.extend_from_slice(&staging[pos..pos + total]);
        pos += total;
    }
    if pos > 0 {
        if !out.is_empty() {
            inbox.lock().unwrap().extend_from_slice(&out);
        }
        staging.drain(..pos);
    }
}

/// The reader loop of a **resident** endpoint: like [`reader_loop`] but
/// with no send-window accounting (the senders live in other processes,
/// whose own windows this process cannot decrement) and the version
/// board fed from every frame header. Exits as soon as shutdown is
/// raised — peer processes own their streams' lifecycles, so waiting for
/// them to close would hang the drop.
fn resident_reader_loop(
    listener: UnixListener,
    me: usize,
    k: usize,
    inboxes: Arc<Vec<Mutex<Vec<u8>>>>,
    board: Arc<Vec<AtomicU64>>,
    shutdown: Arc<AtomicBool>,
) {
    let _ = listener.set_nonblocking(true);
    let mut streams: Vec<Rx> = Vec::new();
    let mut buf = vec![0u8; 16 << 10];
    loop {
        while let Ok((stream, _)) = listener.accept() {
            if let Some(rx) = handshake(stream, k) {
                streams.push(rx);
            }
        }
        let mut moved = false;
        streams.retain_mut(|rx| match rx.stream.read(&mut buf) {
            Ok(0) => false,
            Ok(n) => {
                rx.staging.extend_from_slice(&buf[..n]);
                resident_forward_frames(&mut rx.staging, &inboxes[me], &board);
                moved = true;
                true
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                true
            }
            Err(_) => false,
        });
        if shutdown.load(Ordering::Acquire) {
            return;
        }
        if !moved {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

/// `write_all` over a nonblocking stream: spins through `WouldBlock`
/// (bounded) instead of failing, because the pull service keeps its
/// accepted connections nonblocking for cheap request polling but still
/// needs whole reply frames on the wire.
fn write_all_spin(stream: &mut UnixStream, mut buf: &[u8]) -> std::io::Result<()> {
    let mut spins = 0u32;
    while !buf.is_empty() {
        match stream.write(buf) {
            Ok(0) => return Err(std::io::Error::from(ErrorKind::WriteZero)),
            Ok(n) => buf = &buf[n..],
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::Interrupted) => {
                spins += 1;
                if spins > 1_000_000 {
                    return Err(std::io::Error::from(ErrorKind::TimedOut));
                }
                std::thread::yield_now();
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Ghost transport over Unix-domain sockets: one bound endpoint per shard
/// in a per-run temp directory, one delta connection plus one pull lane
/// per ordered shard pair, one reader thread per endpoint. Frames are
/// staged per connection and flushed with vectored writes; the
/// [`SocketTransport::compressed`] variant (`"socket-z"`) ships
/// shadow-diff frames instead of raw deltas. Borrows the shard view for
/// the duration of the run; dropping it joins the reader threads and
/// removes the socket directory.
pub struct SocketTransport<'g, V> {
    graph: &'g ShardedGraph<V>,
    k: usize,
    dir: PathBuf,
    compress: bool,
    /// Delta write halves, indexed `src * k + dst` (`None` on the
    /// diagonal and for single-shard graphs).
    conns: Vec<Option<Mutex<Connection>>>,
    /// Staged-bytes hint per connection, maintained under the connection
    /// lock: lets `flush_toward` and the drain path skip connections with
    /// nothing staged without taking their locks.
    staged_hint: Vec<AtomicUsize>,
    /// In-flight bytes per connection (staged or written, not yet landed
    /// in the destination inbox): the bounded send window.
    window: Arc<Vec<AtomicUsize>>,
    /// Per-destination inbox of complete delta frames (raw) or envelopes
    /// (compressed).
    inboxes: Arc<Vec<Mutex<Vec<u8>>>>,
    /// Compressed mode: receiver-side diff shadows per destination, keyed
    /// `(src_shard, vertex)` — one inbox mixes every source's lanes.
    rx_shadow: Vec<Mutex<HashMap<(u32, VertexId), Vec<u8>>>>,
    /// Pull lanes, indexed `requester * k + owner`.
    pulls: Vec<Option<Mutex<PullLane>>>,
    send_cap: usize,
    shutdown: Arc<AtomicBool>,
    readers: Vec<std::thread::JoinHandle<()>>,
    backpressure: AtomicU64,
    reconnects: AtomicU64,
    backoffs: AtomicU64,
    lane_timeouts: AtomicU64,
    pipelined: AtomicU64,
    /// `Some(r)` when this instance is the **resident** transport of one
    /// shard inside its own OS process; `None` for the in-process
    /// all-shards topology.
    resident: Option<usize>,
    /// Whether this instance generated (and therefore owns) its socket
    /// dir. A rendezvous dir handed in from outside outlives the drop —
    /// its creator removes it.
    owns_dir: bool,
    /// Resident mode: best master version *announced* per vertex (see
    /// [`ANNOUNCE_LEN`]), behind `known_master_version`. Empty in-process.
    board: Arc<Vec<AtomicU64>>,
    /// Resident mode: the bound owner-side pull-service listener, taken
    /// by `serve_pulls` when the engine starts its service thread.
    pull_listener: Mutex<Option<UnixListener>>,
    /// Resident mode: pull clients toward each remote owner's service,
    /// indexed by owner shard (`None` on the diagonal and in-process).
    pull_clients: Vec<Option<Mutex<PullClient>>>,
}

impl<'g, V> SocketTransport<'g, V> {
    /// Bind the endpoints, connect every shard pair, and spawn the reader
    /// threads, with the default send window and raw frames.
    pub fn new(graph: &'g ShardedGraph<V>) -> std::io::Result<SocketTransport<'g, V>> {
        SocketTransport::with_options(graph, DEFAULT_SEND_BUFFER, false, None)
    }

    /// Like [`SocketTransport::new`] with an explicit per-connection send
    /// window (clamped to at least 1 byte). Tiny windows are useful to
    /// exercise backpressure in tests.
    pub fn with_send_buffer(
        graph: &'g ShardedGraph<V>,
        send_cap: usize,
    ) -> std::io::Result<SocketTransport<'g, V>> {
        SocketTransport::with_options(graph, send_cap, false, None)
    }

    /// The `"socket-z"` variant: delta frames are shadow-diff compressed
    /// ([`super::encode_delta`]) inside `u32 src, u32 len` envelopes, with
    /// an in-band shadow-reset marker keeping reconnects sound. Pull
    /// frames stay raw.
    pub fn compressed(graph: &'g ShardedGraph<V>) -> std::io::Result<SocketTransport<'g, V>> {
        SocketTransport::with_options(graph, DEFAULT_SEND_BUFFER, true, None)
    }

    /// Like [`SocketTransport::new`] but binding every endpoint inside an
    /// explicit rendezvous directory instead of the generated
    /// `graphlab-sock-<pid>-<seq>` temp dir. This is the naming half of
    /// the cross-process story: a parent harness and its children compute
    /// identical socket paths from the shared dir, where the pid-based
    /// scheme (kept as the in-process fallback) diverges per process. The
    /// directory is created if missing and **not** removed on drop — its
    /// creator owns its lifetime.
    pub fn with_rendezvous_dir(
        graph: &'g ShardedGraph<V>,
        dir: impl Into<PathBuf>,
    ) -> std::io::Result<SocketTransport<'g, V>> {
        SocketTransport::with_options(graph, DEFAULT_SEND_BUFFER, false, Some(dir.into()))
    }

    fn with_options(
        graph: &'g ShardedGraph<V>,
        send_cap: usize,
        compress: bool,
        rendezvous: Option<PathBuf>,
    ) -> std::io::Result<SocketTransport<'g, V>> {
        let k = graph.num_shards();
        let (dir, owns_dir) = match rendezvous {
            Some(dir) => {
                // An explicit rendezvous dir belongs to whoever made it;
                // never wipe it, just make sure it exists.
                std::fs::create_dir_all(&dir)?;
                (dir, false)
            }
            None => {
                let dir = next_socket_dir();
                // A stale dir from a crashed run (pid reuse) would fail
                // the binds.
                let _ = std::fs::remove_dir_all(&dir);
                std::fs::create_dir_all(&dir)?;
                (dir, true)
            }
        };
        let shutdown = Arc::new(AtomicBool::new(false));
        let window: Arc<Vec<AtomicUsize>> =
            Arc::new((0..k * k).map(|_| AtomicUsize::new(0)).collect());
        let inboxes: Arc<Vec<Mutex<Vec<u8>>>> =
            Arc::new((0..k).map(|_| Mutex::new(Vec::new())).collect());
        let mut readers = Vec::new();
        if k > 1 {
            for dst in 0..k {
                let listener = UnixListener::bind(Self::endpoint(&dir, dst))?;
                let inboxes = Arc::clone(&inboxes);
                let window = Arc::clone(&window);
                let shutdown = Arc::clone(&shutdown);
                readers.push(
                    std::thread::Builder::new()
                        .name(format!("ghost-rx-{dst}"))
                        .spawn(move || {
                            reader_loop(listener, dst, k, inboxes, window, shutdown, compress)
                        })?,
                );
            }
        }
        let mut conns = Vec::with_capacity(k * k);
        let mut pulls = Vec::with_capacity(k * k);
        for a in 0..k {
            for b in 0..k {
                if a == b || k < 2 {
                    conns.push(None);
                    pulls.push(None);
                } else {
                    conns.push(Some(Mutex::new(Connection::open(
                        &Self::endpoint(&dir, b),
                        a as u32,
                        compress,
                    )?)));
                    let (near, far) = UnixStream::pair()?;
                    // A dead or severed peer must surface as a counted
                    // pull timeout, never hang the admitting worker:
                    // bound every lane read and write.
                    for s in [&near, &far] {
                        s.set_read_timeout(Some(PULL_IO_TIMEOUT))?;
                        s.set_write_timeout(Some(PULL_IO_TIMEOUT))?;
                    }
                    pulls.push(Some(Mutex::new(PullLane { near, far })));
                }
            }
        }
        Ok(SocketTransport {
            graph,
            k,
            dir,
            compress,
            conns,
            staged_hint: (0..k * k).map(|_| AtomicUsize::new(0)).collect(),
            window,
            inboxes,
            rx_shadow: (0..k).map(|_| Mutex::new(HashMap::new())).collect(),
            pulls,
            send_cap: send_cap.max(1),
            shutdown,
            readers,
            backpressure: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            backoffs: AtomicU64::new(0),
            lane_timeouts: AtomicU64::new(0),
            pipelined: AtomicU64::new(0),
            resident: None,
            owns_dir,
            board: Arc::new(Vec::new()),
            pull_listener: Mutex::new(None),
            pull_clients: (0..k).map(|_| None).collect(),
        })
    }

    /// The **resident** constructor: this process runs exactly shard
    /// `my_shard` of `graph`'s partition and every peer shard lives in
    /// its own process, all rendezvousing through `dir` (see the module
    /// docs' "Resident (multi-process) mode"). Binds `shard-<r>.sock`
    /// and `pull-<r>.sock` **before** connecting out to any peer — early
    /// peer connects land in the listen backlog, so fleet launch order
    /// cannot deadlock — then connects a delta connection and a pull
    /// client toward every peer with bounded retry. Resident mode ships
    /// raw frames only; the rendezvous dir belongs to the parent harness
    /// and survives the drop.
    pub fn resident(
        graph: &'g ShardedGraph<V>,
        dir: impl Into<PathBuf>,
        my_shard: usize,
    ) -> std::io::Result<SocketTransport<'g, V>> {
        let k = graph.num_shards();
        assert!(my_shard < k, "resident shard {my_shard} out of range for {k} shards");
        let dir: PathBuf = dir.into();
        std::fs::create_dir_all(&dir)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let window: Arc<Vec<AtomicUsize>> =
            Arc::new((0..k * k).map(|_| AtomicUsize::new(0)).collect());
        let inboxes: Arc<Vec<Mutex<Vec<u8>>>> =
            Arc::new((0..k).map(|_| Mutex::new(Vec::new())).collect());
        let board: Arc<Vec<AtomicU64>> =
            Arc::new((0..graph.num_vertices()).map(|_| AtomicU64::new(0)).collect());
        let delta_listener = UnixListener::bind(Self::endpoint(&dir, my_shard))?;
        let pull_listener = UnixListener::bind(pull_endpoint(&dir, my_shard))?;
        let mut readers = Vec::new();
        {
            let inboxes = Arc::clone(&inboxes);
            let board = Arc::clone(&board);
            let shutdown = Arc::clone(&shutdown);
            readers.push(
                std::thread::Builder::new()
                    .name(format!("ghost-rx-{my_shard}"))
                    .spawn(move || {
                        resident_reader_loop(delta_listener, my_shard, k, inboxes, board, shutdown)
                    })?,
            );
        }
        let mut conns = Vec::with_capacity(k * k);
        for a in 0..k {
            for b in 0..k {
                if a != my_shard || a == b {
                    conns.push(None);
                } else {
                    conns.push(Some(Mutex::new(Connection::open_rendezvous(
                        &Self::endpoint(&dir, b),
                        a as u32,
                    )?)));
                }
            }
        }
        let mut pull_clients = Vec::with_capacity(k);
        for b in 0..k {
            if b == my_shard {
                pull_clients.push(None);
                continue;
            }
            let endpoint = pull_endpoint(&dir, b);
            let stream = connect_retry(&endpoint)?;
            stream.set_read_timeout(Some(RESIDENT_PULL_TIMEOUT))?;
            stream.set_write_timeout(Some(RESIDENT_PULL_TIMEOUT))?;
            pull_clients.push(Some(Mutex::new(PullClient {
                stream: Some(stream),
                endpoint,
                fails: 0,
            })));
        }
        Ok(SocketTransport {
            graph,
            k,
            dir,
            compress: false,
            conns,
            staged_hint: (0..k * k).map(|_| AtomicUsize::new(0)).collect(),
            window,
            inboxes,
            rx_shadow: (0..k).map(|_| Mutex::new(HashMap::new())).collect(),
            pulls: (0..k * k).map(|_| None).collect(),
            send_cap: DEFAULT_SEND_BUFFER.max(1),
            shutdown,
            readers,
            backpressure: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            backoffs: AtomicU64::new(0),
            lane_timeouts: AtomicU64::new(0),
            pipelined: AtomicU64::new(0),
            resident: Some(my_shard),
            owns_dir: false,
            board,
            pull_listener: Mutex::new(Some(pull_listener)),
            pull_clients,
        })
    }

    fn endpoint(dir: &Path, shard: usize) -> PathBuf {
        dir.join(format!("shard-{shard}.sock"))
    }

    /// The temp directory holding this transport's socket files (removed
    /// when the transport drops).
    pub fn socket_dir(&self) -> &Path {
        &self.dir
    }

    /// Reconnections performed after broken-pipe flushes (diagnostics).
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Pull requests that crossed a lane as part of a multi-request
    /// pipelined wave (diagnostics; see [`GhostTransport::pull_many`]).
    pub fn pulls_pipelined(&self) -> u64 {
        self.pipelined.load(Ordering::Relaxed)
    }

    /// Push every frame still staged toward `dst_shard` into the kernel.
    /// Senders are in-process, so the drain path calls this before
    /// sweeping the inbox — a staged frame must never outwait the drain
    /// that would apply it.
    fn flush_toward(&self, dst_shard: usize) {
        for src in 0..self.k {
            let idx = src * self.k + dst_shard;
            if self.staged_hint[idx].load(Ordering::Acquire) == 0 {
                continue;
            }
            let Some(conn) = &self.conns[idx] else { continue };
            let mut c = conn.lock().unwrap();
            if c.staged_bytes > 0 {
                c.flush(dst_shard, &self.window[idx], &self.reconnects, &self.backoffs);
            }
            self.staged_hint[idx].store(0, Ordering::Release);
        }
    }

    /// Fault hook: shut down the `src -> dst` delta connection's stream
    /// so the next flush trips the reconnect-with-backoff path. The
    /// endpoint stays bound, so the reconnect succeeds — this severs one
    /// connection, not the peer.
    pub fn sever_delta_connection(&self, src: usize, dst: usize) {
        if let Some(conn) = &self.conns[src * self.k + dst] {
            let conn = conn.lock().unwrap();
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
    }

    /// Fault hook: shut down both ends of the `requester -> owner` pull
    /// lane; subsequent pulls on the lane fail fast and are counted as
    /// pull timeouts instead of hanging the admitting worker.
    pub fn sever_pull_lane(&self, requester: usize, owner: usize) {
        if let Some(lane) = &self.pulls[requester * self.k + owner] {
            let lane = lane.lock().unwrap();
            let _ = lane.near.shutdown(std::net::Shutdown::Both);
            let _ = lane.far.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl<'g, V: VertexCodec + Clone + Send + Sync> SocketTransport<'g, V> {
    /// Compressed-mode drain: decode envelopes under **both** the inbox
    /// lock and the shadow lock — a diff body is only sound against the
    /// shadow state as of its position in the stream, so a concurrent
    /// drain of the same shard must not decode newer envelopes before
    /// these advance the shadows (the channel-z lane discipline).
    fn drain_compressed(&self, dst_shard: usize) -> DrainReceipt {
        let mut out = DrainReceipt::default();
        let mut inbox = self.inboxes[dst_shard].lock().unwrap();
        if inbox.is_empty() {
            return out;
        }
        let buf = std::mem::take(&mut *inbox);
        let mut shadows = self.rx_shadow[dst_shard].lock().unwrap();
        out.bytes = buf.len() as u64;
        let shard = self.graph.shard(dst_shard);
        let mut rest: &[u8] = &buf;
        let mut payload = Vec::new();
        while rest.len() >= ENVELOPE_HEADER {
            let src = u32::from_le_bytes(rest[..4].try_into().unwrap());
            let len = u32::from_le_bytes(rest[4..8].try_into().unwrap());
            if len == SHADOW_RESET {
                // In-band reset: the sender reconnected and will re-ship
                // its resend set raw; every shadow for it is void.
                shadows.retain(|&(s, _), _| s != src);
                rest = &rest[ENVELOPE_HEADER..];
                continue;
            }
            if rest.len() < ENVELOPE_HEADER + len as usize {
                debug_assert!(false, "torn envelope reached the inbox of shard {dst_shard}");
                break;
            }
            let body = &rest[ENVELOPE_HEADER..ENVELOPE_HEADER + len as usize];
            rest = &rest[ENVELOPE_HEADER + len as usize..];
            let Some((header, after)) = decode_header(body) else {
                debug_assert!(false, "corrupt envelope body on shard {dst_shard}");
                continue;
            };
            let key = (src, header.vertex);
            if decode_payload(&header, after, shadows.get(&key).map(|s| s.as_slice()), &mut payload)
                .is_none()
            {
                debug_assert!(
                    false,
                    "undecodable diff for vertex {} on {dst_shard}",
                    header.vertex
                );
                continue;
            }
            // The shadow advances on EVERY frame — including ones
            // newest-wins rejects below — mirroring the sender's
            // per-encode advance, or the next diff desyncs.
            shadows
                .entry(key)
                .and_modify(|p| {
                    p.clear();
                    p.extend_from_slice(&payload);
                })
                .or_insert_with(|| payload.clone());
            let Some(value) = V::decode(&payload) else {
                debug_assert!(false, "codec round-trip failed for vertex {}", header.vertex);
                continue;
            };
            if let Some(entry) = shard.ghost_of(header.vertex) {
                if entry.store_versioned(&value, header.version) {
                    out.applied += 1;
                    crate::telemetry::instant(
                        crate::telemetry::EventKind::WireApply,
                        header.vertex as u64,
                        header.version,
                    );
                }
            }
        }
        debug_assert!(rest.is_empty(), "trailing bytes in the inbox of shard {dst_shard}");
        // `inbox` stays locked to here so the shadow advance above is
        // ordered against the reader's next append.
        drop(inbox);
        out
    }

    /// Owner+requester halves of one pull whose request frame already
    /// crossed the lane: read it at the owner end, serve the reply, move
    /// it back in lock-step chunks (the same thread plays both ends, so
    /// at most [`PULL_CHUNK`] reply bytes ever sit in a kernel buffer),
    /// and apply it. `Err` means the lane is down (timeout or sever); the
    /// caller counts it.
    fn finish_pull_exchange<'m>(
        &self,
        lane: &mut PullLane,
        dst_shard: usize,
        owner: usize,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> std::io::Result<PullReceipt> {
        let mut raw = [0u8; PullRequest::WIRE_LEN];
        lane.far.read_exact(&mut raw)?;
        let Some(reply) = super::serve_pull(&raw, master) else {
            debug_assert!(false, "corrupt pull request on {dst_shard}->{owner}");
            return Ok(PullReceipt { applied: false, served: true, bytes: 0 });
        };
        let mut got = vec![0u8; reply.len()];
        let mut off = 0usize;
        while off < reply.len() {
            let end = (off + PULL_CHUNK).min(reply.len());
            lane.far.write_all(&reply[off..end])?;
            lane.near.read_exact(&mut got[off..end])?;
            off = end;
        }
        // Requester side: decode the reply and apply it (newest wins).
        let Some(applied) = super::apply_pull_reply(self.graph, dst_shard, &got) else {
            debug_assert!(false, "corrupt pull reply on {owner}->{dst_shard}");
            return Ok(PullReceipt { applied: false, served: true, bytes: reply.len() as u64 });
        };
        Ok(PullReceipt { applied, served: true, bytes: reply.len() as u64 })
    }

    /// Resident mode: one request/reply wave with a remote owner's pull
    /// service over the persistent pull client — all requests in one
    /// batched write, replies read back in order and applied (newest
    /// wins), every reply's version folded into the version board.
    /// Returns `None` on a lane failure: the failure is counted, the
    /// client reconnects (or goes dead after [`PULL_CLIENT_FAILS_MAX`]
    /// strikes), and the wave's remaining receipts stay default.
    fn pull_exchange(
        &self,
        client: &mut PullClient,
        dst_shard: usize,
        reqs: &[PullRequest],
    ) -> Option<Vec<PullReceipt>> {
        if client.dead() || client.stream.is_none() {
            self.lane_timeouts.fetch_add(reqs.len() as u64, Ordering::Relaxed);
            return None;
        }
        let mut batch = Vec::with_capacity(reqs.len() * PullRequest::WIRE_LEN);
        for req in reqs {
            req.encode_into(&mut batch);
        }
        let exchanged = {
            let stream = client.stream.as_mut().unwrap();
            (|| -> std::io::Result<Vec<PullReceipt>> {
                stream.write_all(&batch)?;
                let mut receipts = Vec::with_capacity(reqs.len());
                for _ in reqs {
                    let mut header = [0u8; FRAME_HEADER];
                    stream.read_exact(&mut header)?;
                    let len =
                        u32::from_le_bytes(header[12..16].try_into().unwrap()) as usize;
                    let mut whole = vec![0u8; FRAME_HEADER + len];
                    whole[..FRAME_HEADER].copy_from_slice(&header);
                    stream.read_exact(&mut whole[FRAME_HEADER..])?;
                    let vertex =
                        u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
                    let version = u64::from_le_bytes(header[4..12].try_into().unwrap());
                    if let Some(slot) = self.board.get(vertex) {
                        slot.fetch_max(version, Ordering::AcqRel);
                    }
                    let applied =
                        super::apply_pull_reply(self.graph, dst_shard, &whole).unwrap_or(false);
                    receipts.push(PullReceipt {
                        applied,
                        served: true,
                        bytes: (PullRequest::WIRE_LEN + whole.len()) as u64,
                    });
                }
                Ok(receipts)
            })()
        };
        match exchanged {
            Ok(receipts) => {
                client.fails = 0;
                Some(receipts)
            }
            Err(_) => {
                self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
                client.fail_and_reconnect();
                None
            }
        }
    }

    /// Resident-mode pull path shared by `pull` and `pull_many`: group by
    /// owner, ship [`PULL_WAVE_MAX`]-sized pipelined waves per owner.
    fn resident_pull_many(&self, dst_shard: usize, reqs: &[PullRequest]) -> Vec<PullReceipt> {
        let mut receipts = vec![PullReceipt::default(); reqs.len()];
        let mut by_owner: Vec<Vec<usize>> = vec![Vec::new(); self.k];
        for (i, req) in reqs.iter().enumerate() {
            let owner = self.graph.owner_of(req.vertex);
            if owner != dst_shard {
                by_owner[owner].push(i);
            }
        }
        for (owner, idxs) in by_owner.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let Some(client) = self.pull_clients[owner].as_ref() else { continue };
            let mut client = client.lock().unwrap();
            for wave in idxs.chunks(PULL_WAVE_MAX) {
                let wave_reqs: Vec<PullRequest> = wave.iter().map(|&i| reqs[i]).collect();
                match self.pull_exchange(&mut client, dst_shard, &wave_reqs) {
                    Some(rs) => {
                        if wave.len() > 1 {
                            self.pipelined.fetch_add(wave.len() as u64, Ordering::Relaxed);
                        }
                        for (&i, r) in wave.iter().zip(rs) {
                            receipts[i] = r;
                        }
                    }
                    None => break,
                }
            }
        }
        receipts
    }

    /// The owner-side pull service loop (resident mode; spawned by
    /// `serve_pulls`): accept requester connections on `pull-<r>.sock`,
    /// decode pipelined [`PullRequest`] frames off per-connection staging
    /// buffers, read each requested master row through the engine's
    /// `master` closure (the row lock is held only around the encode
    /// callback, never around socket IO), and write the reply delta frame
    /// back. A connection dying mid-request is dropped; the loop
    /// survives. Once `local_done` flips — every local engine worker
    /// exited — the service writes its `done-<r>` marker and lingers,
    /// still serving, until every peer's marker exists or [`DONE_LINGER`]
    /// expires, so a fast shard cannot strand a slow peer's last
    /// admission pulls.
    fn run_pull_service(
        &self,
        listener: UnixListener,
        master: super::MasterServe<'_, V>,
        local_done: &AtomicBool,
    ) {
        struct Requester {
            stream: UnixStream,
            staging: Vec<u8>,
        }
        let me = self.resident.unwrap_or(0);
        let _ = listener.set_nonblocking(true);
        let mut clients: Vec<Requester> = Vec::new();
        let mut done_since: Option<std::time::Instant> = None;
        let mut ticks = 0u64;
        let mut buf = [0u8; 4096];
        loop {
            while let Ok((stream, _)) = listener.accept() {
                let _ = stream.set_nonblocking(true);
                clients.push(Requester { stream, staging: Vec::new() });
            }
            let mut moved = false;
            clients.retain_mut(|c| {
                match c.stream.read(&mut buf) {
                    // Requester closed (or died): a torn request tail in
                    // staging dies with the connection.
                    Ok(0) => return false,
                    Ok(n) => {
                        c.staging.extend_from_slice(&buf[..n]);
                        moved = true;
                    }
                    Err(e)
                        if matches!(
                            e.kind(),
                            ErrorKind::WouldBlock
                                | ErrorKind::TimedOut
                                | ErrorKind::Interrupted
                        ) => {}
                    Err(_) => return false,
                }
                // Serve every complete request staged so far — a
                // pipelined wave arrives as one batch.
                let mut off = 0usize;
                let mut alive = true;
                while c.staging.len() - off >= PullRequest::WIRE_LEN {
                    let raw = &c.staging[off..off + PullRequest::WIRE_LEN];
                    off += PullRequest::WIRE_LEN;
                    let mut rd = ByteReader::new(raw);
                    let Some(req) = PullRequest::decode_from(&mut rd) else {
                        continue;
                    };
                    debug_assert_eq!(
                        self.graph.owner_of(req.vertex),
                        me,
                        "pull for vertex {} reached non-owner shard {me}",
                        req.vertex
                    );
                    let mut reply = Vec::new();
                    master(req.vertex, &mut |data, version| {
                        debug_assert!(
                            version >= req.min_version,
                            "owner {me} would serve vertex {} at {version}, below the \
                             announced {}",
                            req.vertex,
                            req.min_version
                        );
                        let delta = GhostDelta::from_vertex(req.vertex, version, data);
                        reply.reserve(delta.wire_len());
                        delta.encode_into(&mut reply);
                    });
                    // The row lock dropped with the callback; only now
                    // touch the socket.
                    if write_all_spin(&mut c.stream, &reply).is_err() {
                        alive = false;
                        break;
                    }
                    moved = true;
                }
                if off > 0 {
                    c.staging.drain(..off);
                }
                alive
            });
            if local_done.load(Ordering::Acquire) {
                if done_since.is_none() {
                    let _ = std::fs::write(done_marker(&self.dir, me), b"done");
                    done_since = Some(std::time::Instant::now());
                }
                ticks += 1;
                // Peer-marker sweep, throttled: it is a filesystem scan.
                if ticks % 64 == 0 {
                    let all_done = (0..self.k).all(|r| done_marker(&self.dir, r).exists());
                    if all_done || done_since.map(|t| t.elapsed() > DONE_LINGER).unwrap_or(false)
                    {
                        return;
                    }
                }
            }
            if !moved {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
    }
}

impl<V> Drop for SocketTransport<'_, V> {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        for conn in self.conns.iter().flatten() {
            let conn = conn.lock().unwrap_or_else(|p| p.into_inner());
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
        for client in self.pull_clients.iter().flatten() {
            let client = client.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(stream) = &client.stream {
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
        }
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
        // A rendezvous dir handed in from outside (resident mode, or the
        // explicit in-process variant) belongs to its creator.
        if self.owns_dir {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

impl<V: VertexCodec + Clone + Send + Sync> GhostTransport<V> for SocketTransport<'_, V> {
    fn name(&self) -> &'static str {
        if self.compress {
            "socket-z"
        } else {
            "socket"
        }
    }

    fn send(&self, src_shard: usize, vertex: VertexId, version: u64, data: &V) -> SendReceipt {
        let sites = self.graph.replicas_of(vertex);
        if sites.is_empty() {
            return SendReceipt::default();
        }
        crate::telemetry::instant(
            crate::telemetry::EventKind::WireSend,
            vertex as u64,
            version,
        );
        // Encode once per send, not per replica site.
        let mut payload = Vec::new();
        let mut frame = Vec::new();
        if self.compress {
            data.encode(&mut payload);
        } else {
            let delta = GhostDelta::from_vertex(vertex, version, data);
            frame.reserve(delta.wire_len());
            delta.encode_into(&mut frame);
        }
        // Window-admission estimate: the compressed frame size depends on
        // the per-lane shadow, but is bounded by envelope + varint header
        // + raw payload.
        let est = if self.compress { ENVELOPE_HEADER + payload.len() + 21 } else { frame.len() };
        let mut bytes = 0u64;
        for &(s, gi) in sites {
            let dst = s as usize;
            // Advance the pending slot before the bytes leave so a
            // staleness probe never sees an in-flight version it cannot
            // account for.
            self.graph.shard(dst).ghost(gi as usize).note_pending(version);
            let idx = src_shard * self.k + dst;
            let Some(conn) = &self.conns[idx] else { continue };
            if self.resident.is_some() {
                // Resident fast path: no window accounting (the
                // decrementing reader lives in the peer's process — the
                // kernel's socket buffers are the flow control), and an
                // eager version-announce frame written straight to the
                // stream. The direct write cannot tear frames: every
                // prior write under this lock was a complete frame
                // (`flush` always runs the staged queue to empty), so
                // the stream is frame-aligned at every lock acquisition.
                let mut c = conn.lock().unwrap();
                if c.dead {
                    continue;
                }
                let n = frame.len();
                c.stage(frame.clone());
                let mut announce = [0u8; FRAME_HEADER];
                announce[..4].copy_from_slice(&vertex.to_le_bytes());
                announce[4..12].copy_from_slice(&version.to_le_bytes());
                announce[12..16].copy_from_slice(&ANNOUNCE_LEN.to_le_bytes());
                let _ = c.stream.write_all(&announce);
                if c.staged_bytes >= STAGE_MAX_BYTES || c.staged.len() >= STAGE_MAX_FRAMES {
                    c.flush(dst, &self.window[idx], &self.reconnects, &self.backoffs);
                    self.staged_hint[idx].store(0, Ordering::Release);
                } else {
                    self.staged_hint[idx].store(c.staged_bytes, Ordering::Release);
                }
                bytes += (n + FRAME_HEADER) as u64;
                continue;
            }
            // Bounded send window: block the flush (backpressure) until
            // the reader lands enough in-flight bytes. An empty window
            // always admits the frame, so frames larger than the whole
            // window still make progress. The window is a *soft* bound:
            // the check-then-add is racy across workers of one shard
            // (overshoot of one frame per concurrent sender), and the
            // stall is time-bounded so a reconnect-skewed count can delay
            // a sender but never livelock it.
            let window = &self.window[idx];
            let mut stalled = false;
            // The stall-span clock starts only once the sender actually
            // stalls — the unstalled fast path reads no clock.
            let mut stall_span = crate::telemetry::SPAN_OFF;
            let mut spins = 0u32;
            loop {
                let inflight = window.load(Ordering::Acquire);
                if inflight == 0 || inflight + est <= self.send_cap {
                    break;
                }
                if !stalled {
                    stalled = true;
                    self.backpressure.fetch_add(1, Ordering::Relaxed);
                    stall_span = crate::telemetry::span_start();
                }
                // The window only shrinks once staged bytes reach the
                // kernel and land at the reader: flush our own staged
                // queue from inside the stall, or a sender could block
                // forever on frames it itself staged.
                if let Ok(mut c) = conn.try_lock() {
                    if c.staged_bytes > 0 {
                        c.flush(dst, window, &self.reconnects, &self.backoffs);
                        self.staged_hint[idx].store(0, Ordering::Release);
                    }
                }
                spins += 1;
                if spins > STALL_ITERS_MAX {
                    break;
                }
                if spins < 64 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
            if stalled {
                crate::telemetry::span_end(
                    crate::telemetry::EventKind::Backpressure,
                    stall_span,
                    vertex as u64,
                    dst as u64,
                );
            }
            let mut c = conn.lock().unwrap();
            let n = if self.compress {
                c.stage_compressed(vertex, version, &payload)
            } else {
                let n = frame.len();
                c.stage(frame.clone());
                n
            };
            window.fetch_add(n, Ordering::AcqRel);
            if c.staged_bytes >= STAGE_MAX_BYTES || c.staged.len() >= STAGE_MAX_FRAMES {
                c.flush(dst, window, &self.reconnects, &self.backoffs);
                self.staged_hint[idx].store(0, Ordering::Release);
            } else {
                self.staged_hint[idx].store(c.staged_bytes, Ordering::Release);
            }
            bytes += n as u64;
        }
        SendReceipt { replicas_now: 0, bytes }
    }

    fn drain(&self, dst_shard: usize) -> DrainReceipt {
        let mut out = DrainReceipt::default();
        if self.k < 2 {
            return out;
        }
        if let Some(me) = self.resident {
            debug_assert_eq!(dst_shard, me, "a resident transport only drains its own shard");
            // Cross-process, the senders that need nudging are OUR staged
            // frames toward the peers (the in-process trick of flushing
            // every sender toward `dst` does nothing from here): push
            // them out on every drain tick so peer replicas never wait on
            // a lazy stage queue.
            for peer in 0..self.k {
                if peer != me {
                    self.flush_toward(peer);
                }
            }
        } else {
            // Senders are in-process: staged frames bound for this shard
            // must not outwait the drain that would apply them.
            self.flush_toward(dst_shard);
            if self.compress {
                return self.drain_compressed(dst_shard);
            }
        }
        let buf = {
            let mut q = self.inboxes[dst_shard].lock().unwrap();
            std::mem::take(&mut *q)
        };
        if buf.is_empty() {
            return out;
        }
        out.bytes = buf.len() as u64;
        let shard = self.graph.shard(dst_shard);
        let mut r = ByteReader::new(&buf);
        while !r.is_empty() {
            let Some(delta) = GhostDelta::decode_from(&mut r) else {
                debug_assert!(false, "torn frame reached the inbox of shard {dst_shard}");
                break;
            };
            let Some(value) = delta.decode_vertex::<V>() else {
                debug_assert!(false, "codec round-trip failed for vertex {}", delta.vertex);
                continue;
            };
            if let Some(entry) = shard.ghost_of(delta.vertex) {
                if entry.store_versioned(&value, delta.version) {
                    out.applied += 1;
                    crate::telemetry::instant(
                        crate::telemetry::EventKind::WireApply,
                        delta.vertex as u64,
                        delta.version,
                    );
                }
            }
        }
        out
    }

    fn pull<'m>(
        &self,
        dst_shard: usize,
        req: PullRequest,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> PullReceipt {
        if self.resident.is_some() {
            // Resident mode: the owner's master row lives in another
            // process — the exchange goes through its pull service, and
            // the local `master` closure is never consulted.
            let _ = master;
            return self
                .resident_pull_many(dst_shard, std::slice::from_ref(&req))
                .pop()
                .unwrap_or_default();
        }
        let owner = self.graph.owner_of(req.vertex);
        let Some(lane) = &self.pulls[dst_shard * self.k + owner] else {
            return PullReceipt::default();
        };
        let mut lane = lane.lock().unwrap();
        // Requester -> owner: the request frame crosses the socket. Any
        // lane IO failure — timeout against a dead peer, or a severed
        // lane's broken pipe — fails the pull cleanly and is counted; the
        // engine's scope-admission retry loop owns recovery.
        let mut frame = Vec::with_capacity(PullRequest::WIRE_LEN);
        req.encode_into(&mut frame);
        if lane.near.write_all(&frame).is_err() {
            self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
            return PullReceipt::default();
        }
        match self.finish_pull_exchange(&mut lane, dst_shard, owner, master) {
            Ok(mut r) => {
                r.bytes += PullRequest::WIRE_LEN as u64;
                r
            }
            Err(_) => {
                self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
                PullReceipt::default()
            }
        }
    }

    fn pull_many<'m>(
        &self,
        dst_shard: usize,
        reqs: &[PullRequest],
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> Vec<PullReceipt> {
        let mut receipts = vec![PullReceipt::default(); reqs.len()];
        if self.k < 2 {
            return receipts;
        }
        if self.resident.is_some() {
            let _ = master;
            return self.resident_pull_many(dst_shard, reqs);
        }
        let mut by_owner: Vec<Vec<usize>> = vec![Vec::new(); self.k];
        for (i, req) in reqs.iter().enumerate() {
            let owner = self.graph.owner_of(req.vertex);
            if owner != dst_shard {
                by_owner[owner].push(i);
            }
        }
        for (owner, idxs) in by_owner.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let Some(lane) = &self.pulls[dst_shard * self.k + owner] else { continue };
            let mut lane = lane.lock().unwrap();
            'waves: for wave in idxs.chunks(PULL_WAVE_MAX) {
                // Phase 1: every request frame in the wave crosses the
                // lane in one write before the first reply is served — N
                // pulls pay one syscall and one lane acquisition.
                let mut batch = Vec::with_capacity(wave.len() * PullRequest::WIRE_LEN);
                for &i in wave {
                    reqs[i].encode_into(&mut batch);
                }
                if lane.near.write_all(&batch).is_err() {
                    self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
                    break 'waves;
                }
                if wave.len() > 1 {
                    self.pipelined.fetch_add(wave.len() as u64, Ordering::Relaxed);
                }
                // Phase 2: serve, return, and apply the replies in
                // request order. A lane failure abandons the rest of this
                // owner's requests (default receipts); the engine's
                // per-ghost retry loop owns recovery.
                for &i in wave {
                    match self.finish_pull_exchange(&mut lane, dst_shard, owner, master) {
                        Ok(mut r) => {
                            r.bytes += PullRequest::WIRE_LEN as u64;
                            receipts[i] = r;
                        }
                        Err(_) => {
                            self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
                            break 'waves;
                        }
                    }
                }
            }
        }
        receipts
    }

    fn queued_bytes(&self, dst_shard: usize) -> u64 {
        if self.resident.is_some() {
            // The send windows are unaccounted cross-process; only the
            // local inbox depth is observable.
            return self.inboxes[dst_shard].lock().unwrap().len() as u64;
        }
        let mut total = self.inboxes[dst_shard].lock().unwrap().len() as u64;
        for src in 0..self.k {
            total += self.window[src * self.k + dst_shard].load(Ordering::Acquire) as u64;
        }
        total
    }

    fn finalize(&self) {
        if let Some(me) = self.resident {
            // Ship everything still staged; the landing acknowledgment
            // lives in the peers' processes, so there is no window to
            // wait on — the done-marker barrier in the pull service is
            // the cross-process rendezvous for run completion.
            for peer in 0..self.k {
                if peer != me {
                    self.flush_toward(peer);
                }
            }
            return;
        }
        // Push every staged frame into the kernel first — the window
        // below cannot drain bytes that never left a staging queue.
        for dst in 0..self.k {
            self.flush_toward(dst);
        }
        // Wait (bounded, ~10s) until every written byte has landed in an
        // inbox: senders only write whole frames, so a zero window means
        // the inboxes hold the complete, frame-aligned stream. On timeout
        // — overloaded machine, or a reconnect-skewed window count — warn
        // loudly rather than fail silently: the caller's final drain may
        // miss in-flight deltas.
        for _ in 0..100_000 {
            let inflight: usize =
                self.window.iter().map(|w| w.load(Ordering::Acquire)).sum();
            if inflight == 0 {
                return;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        let inflight: usize = self.window.iter().map(|w| w.load(Ordering::Acquire)).sum();
        eprintln!(
            "graphlab socket transport: finalize timed out with {inflight} bytes \
             in flight; the final drain may miss ghost deltas"
        );
        debug_assert!(false, "socket transport finalize timed out with bytes in flight");
    }

    fn backpressure_stalls(&self) -> u64 {
        self.backpressure.load(Ordering::Relaxed)
    }

    fn pull_timeouts(&self) -> u64 {
        self.lane_timeouts.load(Ordering::Relaxed)
    }

    fn reconnect_backoffs(&self) -> u64 {
        self.backoffs.load(Ordering::Relaxed)
    }

    fn known_master_version(&self, vertex: VertexId, local: u64) -> u64 {
        if self.resident.is_none() {
            return local;
        }
        // Resident mode: the local `master_versions` row of a remote
        // owner never moves — the version board (announce frames + data
        // frame headers + pull replies) is the only witness that the
        // remote master did.
        match self.board.get(vertex as usize) {
            Some(slot) => local.max(slot.load(Ordering::Acquire)),
            None => local,
        }
    }

    fn serve_pulls<'scope, 'env>(
        &'scope self,
        scope: &'scope std::thread::Scope<'scope, 'env>,
        master: super::MasterServe<'scope, V>,
        local_done: &'scope AtomicBool,
    ) -> bool {
        if self.resident.is_none() {
            return false;
        }
        let Some(listener) = self.pull_listener.lock().unwrap().take() else {
            return false;
        };
        std::thread::Builder::new()
            .name(format!("pull-service-{}", self.resident.unwrap_or(0)))
            .spawn_scoped(scope, move || self.run_pull_service(listener, master, local_done))
            .is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DataGraph, GraphBuilder};

    fn chain(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(i as u64);
        }
        for i in 0..n - 1 {
            b.add_undirected(i as u32, i as u32 + 1, (), ());
        }
        b.build()
    }

    /// A bipartite cross: edges (i, n/2 + i). However the partitioner
    /// splits it, two shards end up with several boundary vertices each —
    /// the shape the pull-pipelining test needs.
    fn cross(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(i as u64);
        }
        let h = n / 2;
        for i in 0..h {
            b.add_undirected(i as u32, (h + i) as u32, (), ());
        }
        b.build()
    }

    /// Poll `drain` until `want` applies land (bounded): flushes are
    /// asynchronous to the reader thread, so tests wait rather than race.
    fn drain_until<V: VertexCodec + Clone + Send + Sync>(
        t: &SocketTransport<'_, V>,
        dst: usize,
        want: u64,
    ) -> u64 {
        let mut applied = 0;
        for _ in 0..10_000 {
            applied += GhostTransport::drain(t, dst).applied;
            if applied >= want {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        applied
    }

    #[test]
    fn deltas_cross_the_socket_and_apply_on_drain() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        assert!(t.socket_dir().exists(), "socket files live in the temp dir");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        let r = GhostTransport::send(&t, owner, v, 4, &777u64);
        assert!(r.bytes > 0, "socket backend really ships bytes");
        assert_eq!(r.replicas_now, 0, "socket applies at drain, not send");
        assert_eq!(entry.pending_version(), 4, "in-flight version visible");
        GhostTransport::finalize(&t);
        let d = GhostTransport::drain(&t, dst as usize);
        assert_eq!(d.applied, 1);
        assert_eq!(d.bytes, r.bytes, "every shipped byte consumed");
        assert_eq!(entry.read(), 777, "payload round-tripped the socket");
        assert_eq!(entry.version(), 4);
        assert_eq!(GhostTransport::queued_bytes(&t, dst as usize), 0);

        let dir = t.socket_dir().to_path_buf();
        drop(t);
        assert!(!dir.exists(), "socket files cleaned up on drop");
    }

    #[test]
    fn severed_delta_connection_reconnects_with_backoff() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);
        t.sever_delta_connection(owner, dst as usize);
        let r = GhostTransport::send(&t, owner, v, 2, &555u64);
        assert!(r.bytes > 0);
        // The send only *staged* the frame; the drain's flush hits the
        // severed stream and must reconnect. Poll the drain (bounded)
        // rather than finalize — the torn write skews the window
        // accounting, which finalize only tolerates noisily.
        assert_eq!(drain_until(&t, dst as usize, 1), 1, "severed frame resent and applied");
        assert!(t.reconnects() >= 1, "a broken pipe must reconnect");
        assert!(
            GhostTransport::reconnect_backoffs(&t) >= 1,
            "each reconnect attempt waits one counted backoff"
        );
        assert_eq!(entry.read(), 555);
        assert_eq!(entry.version(), 2);
    }

    #[test]
    fn severed_pull_lane_fails_fast_and_counts_a_timeout() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, _gi) = sg.replicas_of(v)[0];
        t.sever_pull_lane(dst as usize, owner);
        let master = 999u64;
        let r = GhostTransport::pull(
            &t,
            dst as usize,
            PullRequest { vertex: v, min_version: 1 },
            &|u| {
                assert_eq!(u, v);
                (&master, 1)
            },
        );
        assert!(!r.applied && !r.served, "a severed lane fails the pull cleanly");
        assert_eq!(GhostTransport::pull_timeouts(&t), 1, "the failure is counted");
    }

    #[test]
    fn partial_frames_never_reach_the_inbox() {
        let inbox = Mutex::new(Vec::new());
        let d = GhostDelta::from_vertex(3, 9, &1234u64);
        let mut frame = Vec::new();
        d.encode_into(&mut frame);
        // Deliver the frame in three fragments: nothing forwards until the
        // final fragment completes it.
        let mut staging = Vec::new();
        staging.extend_from_slice(&frame[..10]);
        forward_frames(&mut staging, &inbox, false);
        assert!(inbox.lock().unwrap().is_empty());
        staging.extend_from_slice(&frame[10..frame.len() - 1]);
        forward_frames(&mut staging, &inbox, false);
        assert!(inbox.lock().unwrap().is_empty());
        staging.extend_from_slice(&frame[frame.len() - 1..]);
        forward_frames(&mut staging, &inbox, false);
        assert_eq!(*inbox.lock().unwrap(), frame);
        assert!(staging.is_empty());
    }

    #[test]
    fn partial_envelopes_never_reach_the_inbox() {
        let inbox = Mutex::new(Vec::new());
        // A reset marker followed by one compressed envelope.
        let mut stream = Vec::new();
        put_u32(&mut stream, 1);
        put_u32(&mut stream, SHADOW_RESET);
        let at = stream.len();
        put_u32(&mut stream, 1);
        put_u32(&mut stream, 0);
        let payload = [7u8; 24];
        let body_len = encode_delta(3, 9, &payload, None, &mut stream);
        stream[at + 4..at + 8].copy_from_slice(&(body_len as u32).to_le_bytes());
        // Cut inside the second envelope's body: only the reset (a
        // complete, body-less envelope) may forward.
        let cut = at + ENVELOPE_HEADER + 2;
        let mut staging = Vec::new();
        staging.extend_from_slice(&stream[..cut]);
        forward_frames(&mut staging, &inbox, true);
        assert_eq!(inbox.lock().unwrap().len(), ENVELOPE_HEADER, "only the reset forwards");
        staging.extend_from_slice(&stream[cut..]);
        forward_frames(&mut staging, &inbox, true);
        assert_eq!(*inbox.lock().unwrap(), stream);
        assert!(staging.is_empty());
    }

    #[test]
    fn socket_z_round_trips_and_shrinks_repeat_frames() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::compressed(&sg).expect("socket setup");
        assert_eq!(GhostTransport::name(&t), "socket-z");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        // First ship is raw (no shadow yet); the re-ship of an identical
        // payload diffs down to a few bytes.
        let r1 = GhostTransport::send(&t, owner, v, 1, &777u64);
        let r2 = GhostTransport::send(&t, owner, v, 2, &777u64);
        assert!(r1.bytes > 0 && r2.bytes > 0);
        assert!(
            r2.bytes < r1.bytes,
            "unchanged payload must diff smaller ({} vs {})",
            r2.bytes,
            r1.bytes
        );
        let raw_wire = GhostDelta::from_vertex(v, 2, &777u64).wire_len() as u64;
        assert!(r2.bytes < raw_wire, "diff frame beats the raw wire frame");
        GhostTransport::finalize(&t);
        let d = GhostTransport::drain(&t, dst as usize);
        assert_eq!(d.applied, 2, "both versions apply in order");
        assert_eq!(d.bytes, r1.bytes + r2.bytes, "every shipped byte consumed");
        assert_eq!(entry.read(), 777);
        assert_eq!(entry.version(), 2);
        assert_eq!(GhostTransport::queued_bytes(&t, dst as usize), 0);
    }

    #[test]
    fn socket_z_reconnect_resets_diff_shadows() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::compressed(&sg).expect("socket setup");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        // Establish diff shadows on both ends, then kill the connection:
        // the resend must cross as reset + raw and still reconstruct.
        let _ = GhostTransport::send(&t, owner, v, 1, &111u64);
        GhostTransport::finalize(&t);
        assert_eq!(drain_until(&t, dst as usize, 1), 1);
        assert_eq!(entry.read(), 111);
        t.sever_delta_connection(owner, dst as usize);
        let _ = GhostTransport::send(&t, owner, v, 2, &222u64);
        assert_eq!(drain_until(&t, dst as usize, 1), 1, "resent frame applies");
        assert!(t.reconnects() >= 1, "the severed flush reconnected");
        assert_eq!(entry.read(), 222, "payload reconstructed after the shadow reset");
        assert_eq!(entry.version(), 2);
    }

    #[test]
    fn pull_many_pipelines_requests_toward_each_owner() {
        let mut g = cross(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        let masters: Vec<u64> = (0..8u64).map(|i| 1000 + i).collect();
        let mut tested = false;
        for dst in 0..2usize {
            let reqs: Vec<PullRequest> = (0..8u32)
                .filter(|&v| {
                    sg.owner_of(v) != dst
                        && sg.replicas_of(v).iter().any(|&(s, _)| s as usize == dst)
                })
                .map(|v| PullRequest { vertex: v, min_version: 1 })
                .collect();
            if reqs.len() < 2 {
                continue;
            }
            tested = true;
            let before = t.pulls_pipelined();
            let receipts =
                GhostTransport::pull_many(&t, dst, &reqs, &|u| (&masters[u as usize], 1));
            assert_eq!(receipts.len(), reqs.len());
            for (req, r) in reqs.iter().zip(&receipts) {
                assert!(r.served, "vertex {} served", req.vertex);
                assert!(r.applied, "vertex {} applied", req.vertex);
                assert!(r.bytes > PullRequest::WIRE_LEN as u64);
                let (s, gi) = *sg
                    .replicas_of(req.vertex)
                    .iter()
                    .find(|&&(s, _)| s as usize == dst)
                    .unwrap();
                let entry = sg.shard(s as usize).ghost(gi as usize);
                assert_eq!(entry.read(), masters[req.vertex as usize]);
            }
            assert!(
                t.pulls_pipelined() - before >= reqs.len() as u64,
                "more than one pull was in flight on the lane"
            );
        }
        assert!(tested, "the cross graph must yield a shard with >= 2 remote ghosts");
    }

    /// A fresh rendezvous dir for resident-mode tests, in the role of the
    /// parent harness (which owns the dir's lifetime).
    fn fresh_rendezvous(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("graphlab-rdv-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Read one pull-reply delta frame off a raw requester stream and
    /// decode its `u64` payload.
    fn read_reply(stream: &mut UnixStream) -> (u32, u64, u64) {
        let mut header = [0u8; FRAME_HEADER];
        stream.read_exact(&mut header).expect("reply header");
        let vertex = u32::from_le_bytes(header[..4].try_into().unwrap());
        let version = u64::from_le_bytes(header[4..12].try_into().unwrap());
        let len = u32::from_le_bytes(header[12..16].try_into().unwrap()) as usize;
        let mut whole = vec![0u8; FRAME_HEADER + len];
        whole[..FRAME_HEADER].copy_from_slice(&header);
        stream.read_exact(&mut whole[FRAME_HEADER..]).expect("reply payload");
        let mut r = ByteReader::new(&whole);
        let delta = GhostDelta::decode_from(&mut r).expect("reply frame decodes");
        (vertex, version, delta.decode_vertex::<u64>().expect("payload decodes"))
    }

    #[test]
    fn pull_service_serves_concurrent_waves_and_survives_torn_requesters() {
        let dir = fresh_rendezvous("service");
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 1);
        let t = SocketTransport::resident(&sg, &dir, 0).expect("resident setup");
        let masters: Vec<u64> = (0..8u64).map(|i| 5000 + i).collect();
        let master_fn = |u: VertexId, out: &mut dyn FnMut(&u64, u64)| {
            out(&masters[u as usize], 7);
        };
        let done = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let master: crate::transport::MasterServe<'_, u64> = &master_fn;
            assert!(
                GhostTransport::serve_pulls(&t, scope, master, &done),
                "a resident transport spawns its pull service"
            );
            // A requester that dies mid-request: five bytes of a twelve
            // byte frame, then gone. The service must shrug it off.
            {
                let mut torn = UnixStream::connect(pull_endpoint(&dir, 0)).unwrap();
                torn.write_all(&[1, 2, 3, 4, 5]).unwrap();
                let _ = torn.shutdown(std::net::Shutdown::Both);
            }
            // Two concurrent fake requester processes, each shipping one
            // pipelined wave and reading the replies back in order.
            let waves: [Vec<u32>; 2] = [vec![0, 1, 2, 3], vec![4, 5, 6, 7]];
            let mut requesters = Vec::new();
            for wave in &waves {
                let masters = &masters;
                let dir = &dir;
                requesters.push(scope.spawn(move || {
                    let mut stream = UnixStream::connect(pull_endpoint(dir, 0)).unwrap();
                    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                    let mut batch = Vec::new();
                    for &u in wave {
                        PullRequest { vertex: u, min_version: 7 }.encode_into(&mut batch);
                    }
                    stream.write_all(&batch).unwrap();
                    for &u in wave {
                        let (vertex, version, value) = read_reply(&mut stream);
                        assert_eq!(vertex, u, "replies come back in request order");
                        assert_eq!(version, 7);
                        assert_eq!(value, masters[u as usize]);
                    }
                }));
            }
            for r in requesters {
                r.join().expect("requester thread");
            }
            // One more requester after the torn one proves the loop is
            // still alive and serving.
            let mut late = UnixStream::connect(pull_endpoint(&dir, 0)).unwrap();
            late.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            let mut one = Vec::new();
            PullRequest { vertex: 3, min_version: 7 }.encode_into(&mut one);
            late.write_all(&one).unwrap();
            assert_eq!(read_reply(&mut late).2, masters[3]);
            // Clean shutdown: the engine's workers finishing flips the
            // done flag (run_core does this right before `finalize`);
            // with k = 1 the service's own marker completes the fleet and
            // the scope join below proves the thread exited.
            done.store(true, Ordering::Release);
        });
        assert!(done_marker(&dir, 0).exists(), "the service wrote its done marker");
        drop(t);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resident_pair_announces_versions_and_pulls_through_owner_service() {
        let dir = fresh_rendezvous("pair");
        let mut g1 = chain(8);
        let mut g2 = chain(8);
        // Each "process" builds the partition independently and
        // deterministically, exactly like real resident children.
        let sg1 = ShardedGraph::new(&mut g1, 2);
        let sg2 = ShardedGraph::new(&mut g2, 2);
        let barrier = std::sync::Barrier::new(2);
        std::thread::scope(|s| {
            // Owner side: shard 0's resident transport plus its pull
            // service, the only path to shard 0's master rows.
            s.spawn(|| {
                let v = (0..8u32)
                    .find(|&u| {
                        sg1.owner_of(u) == 0
                            && sg1.replicas_of(u).iter().any(|&(sh, _)| sh == 1)
                    })
                    .expect("a shard-0-owned boundary vertex");
                let t0 = SocketTransport::resident(&sg1, &dir, 0).expect("resident 0");
                let val = AtomicU64::new(999);
                let ver = AtomicU64::new(5);
                let master_fn = |u: VertexId, out: &mut dyn FnMut(&u64, u64)| {
                    let _ = u;
                    let snapshot = val.load(Ordering::Acquire);
                    out(&snapshot, ver.load(Ordering::Acquire));
                };
                let done = AtomicBool::new(false);
                std::thread::scope(|scope| {
                    let master: crate::transport::MasterServe<'_, u64> = &master_fn;
                    assert!(GhostTransport::serve_pulls(&t0, scope, master, &done));
                    let r = GhostTransport::send(&t0, 0, v, 5, &999u64);
                    assert!(r.bytes > 0);
                    barrier.wait(); // announce is on the wire
                    barrier.wait(); // peer finished its pull
                    val.store(1234, Ordering::Release);
                    ver.store(6, Ordering::Release);
                    let _ = GhostTransport::send(&t0, 0, v, 6, &1234u64);
                    // A resident drain flushes this shard's staged frames
                    // toward every peer.
                    let _ = GhostTransport::drain(&t0, 0);
                    barrier.wait(); // data frames flushed
                    barrier.wait(); // peer drained and wrote done-1
                    done.store(true, Ordering::Release);
                });
            });
            // Requester side: shard 1's resident transport.
            s.spawn(|| {
                let v = (0..8u32)
                    .find(|&u| {
                        sg2.owner_of(u) == 0
                            && sg2.replicas_of(u).iter().any(|&(sh, _)| sh == 1)
                    })
                    .expect("a shard-0-owned boundary vertex");
                let t1 = SocketTransport::resident(&sg2, &dir, 1).expect("resident 1");
                barrier.wait(); // announce is on the wire
                // The eager announce frame raises the version board while
                // the data frame itself is still staged in the peer.
                let mut known = 0;
                for _ in 0..10_000 {
                    known = GhostTransport::known_master_version(&t1, v, 0);
                    if known >= 5 {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                assert_eq!(known, 5, "announce frame fed the version board before any flush");
                static ZERO: u64 = 0;
                let receipt = GhostTransport::pull(
                    &t1,
                    1,
                    PullRequest { vertex: v, min_version: 5 },
                    &|_| (&ZERO, 0),
                );
                assert!(receipt.served, "the owner-side service answered");
                assert!(receipt.applied, "the reply applied to the ghost");
                assert!(receipt.bytes > PullRequest::WIRE_LEN as u64);
                let (_, gi) = *sg2
                    .replicas_of(v)
                    .iter()
                    .find(|&&(sh, _)| sh == 1)
                    .unwrap();
                let entry = sg2.shard(1).ghost(gi as usize);
                assert_eq!(entry.read(), 999, "pull fetched the owner's master row");
                assert_eq!(entry.version(), 5);
                barrier.wait(); // tell the owner the pull landed
                barrier.wait(); // data frames flushed
                let applied = drain_until(&t1, 1, 1);
                assert!(applied >= 1, "flushed delta frames apply on a resident drain");
                assert_eq!(entry.read(), 1234);
                assert_eq!(entry.version(), 6);
                std::fs::write(done_marker(&dir, 1), b"done").unwrap();
                barrier.wait();
            });
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
}
