//! The **Unix-domain-socket backend**: ghost deltas and staleness pulls
//! moved as real kernel-socket bytes between per-shard endpoints — the
//! in-process rehearsal of a true multi-process deployment, std-only, no
//! filesystem footprint beyond a per-run temp directory of socket files
//! (removed on drop, so parallel test binaries never collide).
//!
//! # Wire format
//!
//! The raw backend (`"socket"`) ships exactly the parent `transport`
//! module's two frame kinds, byte-for-byte:
//!
//! * **delta frames** (`u32 vertex, u64 version, u32 len, payload`) flow
//!   over one `UnixStream` per ordered shard pair into the destination
//!   endpoint; replicas apply **newest-wins** at [`GhostTransport::drain`]
//!   (`GhostEntry::store_versioned`), so frames reordered across
//!   connections — or re-sent after a reconnect — are harmless;
//! * **pull frames** (`u32 vertex, u64 min_version`, fixed
//!   [`PullRequest::WIRE_LEN`] bytes) cross a dedicated request/reply
//!   socketpair lane per ordered shard pair; the reply is an ordinary
//!   delta frame carrying the owner's current master data.
//!
//! The compressed backend ([`SocketTransport::compressed`], exposed as
//! `"socket-z"`) replaces the delta frame with the shadow-diff frame of
//! [`super::encode_delta`] wrapped in an 8-byte envelope:
//!
//! ```text
//! envelope := u32 src_shard, u32 body_len, body
//! body     := one compressed delta frame (varint header + diff/raw body)
//! reset    := u32 src_shard, u32 0xFFFF_FFFF   (no body)
//! ```
//!
//! The `src` field keys the receiver's per-`(src, vertex)` diff shadows
//! (one inbox mixes every source), and the in-band **reset marker** voids
//! every shadow for its source — the sender emits one after a reconnect
//! and re-ships everything staged since its last complete flush as raw
//! frames, so a dropped connection can never desync the diff shadows.
//! Pull frames stay raw on both variants.
//!
//! # Topology & delivery
//!
//! Each shard binds one endpoint (`shard-<i>.sock`) in a unique temp
//! directory; every other shard connects to it and identifies itself with
//! a 4-byte handshake. **One reader thread serves each endpoint**: it
//! accepts connections (including re-connections), moves received bytes
//! into per-stream staging buffers, and forwards only *complete* frames
//! to the endpoint inbox — a torn write from a dropped connection can
//! never corrupt the frame stream, and the sender's retry after a
//! reconnect lands cleanly. Workers apply inboxed frames on their normal
//! [`GhostTransport::drain`] cadence.
//!
//! # Vectored writes
//!
//! Sends do not hit the kernel one frame at a time: each connection
//! **stages** encoded frames in a queue and flushes them with a single
//! `write_vectored` (writev) syscall once [`STAGE_MAX_BYTES`] /
//! [`STAGE_MAX_FRAMES`] accumulate — or earlier, when the destination
//! drains (senders are in-process, so [`GhostTransport::drain`] first
//! pushes everything still staged toward it), at [`GhostTransport::finalize`],
//! and from inside a backpressured sender's own stall loop (a sender must
//! be able to land the bytes it itself staged, or a tiny send window
//! would deadlock).
//!
//! # Backpressure & reconnect
//!
//! Every connection has a **bounded send window** (default
//! [`DEFAULT_SEND_BUFFER`] bytes of in-flight data, configurable down to
//! bytes for tests): a send that would overflow it blocks — stalling the
//! engine's batcher flush, which is the intended flow control — until the
//! reader lands enough bytes, and each stalled send increments the
//! [`GhostTransport::backpressure_stalls`] counter. A frame larger than
//! the whole window is sent alone once the window is empty, so progress
//! is always possible. Flushes that fail with a broken pipe reconnect to
//! the endpoint (fresh handshake) under **capped exponential backoff** —
//! a deterministic 2, 4, 8, …, 64 ms schedule, each wait counted in
//! [`GhostTransport::reconnect_backoffs`] — and resend every frame staged
//! since the last complete flush (raw mode resends the staged queue
//! verbatim; compressed mode re-encodes it raw behind a shadow-reset
//! marker); exhausting the attempt budget panics with the shard pair in
//! the message, never drops a delta silently. Pull lanes carry read and
//! write timeouts, so a crashed peer surfaces as a counted
//! [`GhostTransport::pull_timeouts`] failure (retried by the engine's
//! scope-admission backoff loop) instead of hanging the admitting worker.
//! [`SocketTransport::sever_delta_connection`] and
//! [`SocketTransport::sever_pull_lane`] let fault tests trip both paths
//! on demand.
//!
//! # Pull pipelining
//!
//! [`GhostTransport::pull_many`] batches a scope's stale-ghost refreshes:
//! all request frames bound for one owner cross the lane in a single
//! write before the first reply is served, so N staleness pulls cost one
//! lane acquisition and one request syscall instead of N lock-step
//! round-trips ([`SocketTransport::pulls_pipelined`] counts them).

use super::{
    decode_header, decode_payload, encode_delta, put_u32, ByteReader, DrainReceipt, GhostDelta,
    GhostTransport, PullReceipt, PullRequest, SendReceipt, VertexCodec,
};
use crate::graph::{ShardedGraph, VertexId};
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, IoSlice, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Default per-connection bounded send window, in bytes of in-flight
/// (sent but not yet received) data.
pub const DEFAULT_SEND_BUFFER: usize = 1 << 20;

/// Delta frame header size: `u32 vertex + u64 version + u32 payload_len`.
const FRAME_HEADER: usize = 16;

/// Compressed-mode envelope header: `u32 src_shard + u32 body_len`.
const ENVELOPE_HEADER: usize = 8;

/// Sentinel `body_len` marking a shadow-reset envelope (no body): the
/// receiver voids every diff shadow for the envelope's source shard. A
/// real body can never reach this length.
const SHADOW_RESET: u32 = u32::MAX;

/// Flush the staged frame queue to the kernel (one writev) once it holds
/// this many bytes.
const STAGE_MAX_BYTES: usize = 32 << 10;

/// Flush the staged frame queue once it holds this many frames, whatever
/// their byte total — bounds the iovec length handed to `write_vectored`.
const STAGE_MAX_FRAMES: usize = 64;

/// Max pull requests in flight on one lane per pipelined wave: bounds the
/// kernel buffer the batched request write can occupy (the requester
/// thread plays both lane ends, so unread requests sit in the socketpair
/// buffer until phase 2 serves them).
const PULL_WAVE_MAX: usize = 64;

/// Chunk size for the lock-step pull exchange: the requester thread plays
/// both ends of the lane, so no more than this many reply bytes are ever
/// in a kernel buffer — the exchange can never deadlock on buffer space.
const PULL_CHUNK: usize = 16 << 10;

/// How many reconnect attempts a broken-pipe flush gets before giving up
/// and panicking with the shard-pair context.
const RECONNECT_ATTEMPTS_MAX: u32 = 8;

/// Ceiling of the reconnect backoff schedule: waits double per attempt
/// (2, 4, 8, … ms) and cap here. Deterministic — no wall-clock jitter.
const RECONNECT_BACKOFF_CAP_MS: u64 = 64;

/// Read/write timeout on pull-lane sockets: a crashed or severed peer
/// fails the exchange (counted as a pull timeout) instead of hanging the
/// admitting worker indefinitely.
const PULL_IO_TIMEOUT: Duration = Duration::from_millis(500);

/// Upper bound on one send's backpressure stall (64 yields, then 50µs
/// sleeps — roughly one second). Keeps the soft window bound from ever
/// livelocking a sender if reconnect-torn accounting leaks the window
/// shut.
const STALL_ITERS_MAX: u32 = 20_000;

/// A unique socket directory per transport instance: process id plus an
/// in-process sequence number, so parallel test binaries (and parallel
/// tests within one binary) never collide on socket paths.
fn next_socket_dir() -> PathBuf {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("graphlab-sock-{}-{seq}", std::process::id()))
}

/// Write half of one `src -> dst` delta connection, with its staged-frame
/// queue and (compressed mode) the sender-side diff shadows.
struct Connection {
    stream: UnixStream,
    endpoint: PathBuf,
    src: u32,
    compress: bool,
    /// Whole encoded frames (raw delta frames, or compressed envelopes)
    /// staged but not yet handed to the kernel.
    staged: VecDeque<Vec<u8>>,
    staged_bytes: usize,
    /// Compressed mode: payload as of the last frame encoded per vertex —
    /// the diff base the receiver's shadow mirrors.
    shadow: HashMap<VertexId, Vec<u8>>,
    /// Compressed mode: `(vertex, version, payload)` of every frame staged
    /// since the last complete flush — the raw resend set after a
    /// reconnect (cleared once a flush fully lands).
    meta: Vec<(VertexId, u64, Vec<u8>)>,
}

impl Connection {
    fn open(endpoint: &Path, src: u32, compress: bool) -> std::io::Result<Connection> {
        let mut stream = UnixStream::connect(endpoint)?;
        stream.write_all(&src.to_le_bytes())?;
        Ok(Connection {
            stream,
            endpoint: endpoint.to_path_buf(),
            src,
            compress,
            staged: VecDeque::new(),
            staged_bytes: 0,
            shadow: HashMap::new(),
            meta: Vec::new(),
        })
    }

    /// Queue one whole encoded frame for the next flush.
    fn stage(&mut self, frame: Vec<u8>) {
        self.staged_bytes += frame.len();
        self.staged.push_back(frame);
    }

    /// Compressed mode: encode `(vertex, version, payload)` as a diff
    /// against this lane's shadow (raw on first ship), wrap it in the
    /// `u32 src, u32 body_len` envelope, advance the shadow, and stage
    /// it. Returns the staged envelope length.
    fn stage_compressed(&mut self, vertex: VertexId, version: u64, payload: &[u8]) -> usize {
        let mut envelope = Vec::with_capacity(ENVELOPE_HEADER + payload.len() + 21);
        put_u32(&mut envelope, self.src);
        put_u32(&mut envelope, 0); // body_len, patched below
        let body_len =
            encode_delta(vertex, version, payload, self.shadow.get(&vertex).map(|s| s.as_slice()), &mut envelope);
        debug_assert!((body_len as u32) < SHADOW_RESET);
        envelope[4..8].copy_from_slice(&(body_len as u32).to_le_bytes());
        self.shadow
            .entry(vertex)
            .and_modify(|p| {
                p.clear();
                p.extend_from_slice(payload);
            })
            .or_insert_with(|| payload.to_vec());
        self.meta.push((vertex, version, payload.to_vec()));
        let n = envelope.len();
        self.stage(envelope);
        n
    }

    /// Hand the whole staged queue to the kernel with as few
    /// `write_vectored` (writev) syscalls as it takes, reconnecting with
    /// capped backoff on a broken pipe. Frames the kernel accepted only
    /// partially stay at the queue front minus the written prefix — the
    /// reader forwards only complete frames, so a torn tail that dies
    /// with a dropped connection is simply resent whole. On return the
    /// queue is empty and (compressed mode) the resend set is cleared.
    fn flush(
        &mut self,
        dst: usize,
        window: &AtomicUsize,
        reconnects: &AtomicU64,
        backoffs: &AtomicU64,
    ) {
        let mut attempt = 0u32;
        while !self.staged.is_empty() {
            let res = {
                let slices: Vec<IoSlice<'_>> =
                    self.staged.iter().map(|f| IoSlice::new(f.as_slice())).collect();
                self.stream.write_vectored(&slices)
            };
            match res {
                // A zero-length write with frames still staged cannot make
                // progress: treat it like a dead connection.
                Ok(0) => self.reconnect_and_restage(dst, window, reconnects, backoffs, &mut attempt),
                Ok(n) => {
                    self.staged_bytes -= n;
                    let mut left = n;
                    while left > 0 {
                        let front = self.staged.front_mut().unwrap();
                        if left >= front.len() {
                            left -= front.len();
                            self.staged.pop_front();
                        } else {
                            front.drain(..left);
                            left = 0;
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::BrokenPipe
                            | ErrorKind::ConnectionReset
                            | ErrorKind::ConnectionAborted
                            | ErrorKind::NotConnected
                            | ErrorKind::WriteZero
                    ) =>
                {
                    self.reconnect_and_restage(dst, window, reconnects, backoffs, &mut attempt)
                }
                Err(e) => panic!(
                    "ghost delta flush (shard {} -> {dst}) to {:?} failed: {e}",
                    self.src, self.endpoint
                ),
            }
        }
        self.meta.clear();
    }

    /// Reconnect after a broken-pipe flush (counted, capped-exponential
    /// backoff) and rebuild the staged queue for the fresh connection.
    ///
    /// Raw mode keeps the queue verbatim — raw frames are self-contained
    /// and newest-wins makes duplicates harmless. Compressed mode must
    /// also repair the diff shadows: the receiver may have applied some,
    /// none, or all of the staged diffs before the connection died, so
    /// the resend is one contiguous buffer of a shadow-reset marker
    /// followed by every frame staged since the last complete flush,
    /// re-encoded **raw** — after which both ends' shadows agree again
    /// (exactly the resend set, last write per vertex).
    ///
    /// Each reconnect re-adds the resend bytes to `window`: the reader
    /// decremented every raw byte it received off the old connection
    /// (including torn tails), so without the re-add a resend could drive
    /// the window negative and let `finalize` return with bytes still in
    /// flight. The accounting errs toward a bounded *over*-count per
    /// reconnect; the send path's stall loop is time-bounded for exactly
    /// this reason.
    fn reconnect_and_restage(
        &mut self,
        dst: usize,
        window: &AtomicUsize,
        reconnects: &AtomicU64,
        backoffs: &AtomicU64,
        attempt: &mut u32,
    ) {
        *attempt += 1;
        assert!(
            *attempt <= RECONNECT_ATTEMPTS_MAX,
            "ghost delta flush (shard {src} -> {dst}) to {:?} failed after \
             {RECONNECT_ATTEMPTS_MAX} reconnect attempts with {} staged frames",
            self.endpoint,
            self.staged.len(),
            src = self.src,
        );
        reconnects.fetch_add(1, Ordering::Relaxed);
        backoffs.fetch_add(1, Ordering::Relaxed);
        crate::telemetry::instant(
            crate::telemetry::EventKind::SocketReconnect,
            dst as u64,
            *attempt as u64,
        );
        let wait = (1u64 << *attempt).min(RECONNECT_BACKOFF_CAP_MS);
        std::thread::sleep(Duration::from_millis(wait));
        if let Ok(fresh) = Connection::open(&self.endpoint, self.src, self.compress) {
            self.stream = fresh.stream;
        }
        if self.compress {
            let mut resend = Vec::new();
            put_u32(&mut resend, self.src);
            put_u32(&mut resend, SHADOW_RESET);
            for (vertex, version, payload) in &self.meta {
                let at = resend.len();
                put_u32(&mut resend, self.src);
                put_u32(&mut resend, 0);
                let body_len = encode_delta(*vertex, *version, payload, None, &mut resend);
                resend[at + 4..at + 8].copy_from_slice(&(body_len as u32).to_le_bytes());
            }
            self.shadow.clear();
            for (vertex, _, payload) in &self.meta {
                self.shadow.insert(*vertex, payload.clone());
            }
            window.fetch_add(resend.len(), Ordering::AcqRel);
            self.staged_bytes = resend.len();
            self.staged.clear();
            self.staged.push_back(resend);
        } else {
            window.fetch_add(self.staged_bytes, Ordering::AcqRel);
        }
    }
}

/// The request/reply socketpair lane one ordered shard pair uses for
/// staleness pulls. `near` is the requester's end, `far` the owner's.
struct PullLane {
    near: UnixStream,
    far: UnixStream,
}

/// One accepted inbound stream at an endpoint, with its frame-staging
/// buffer (bytes received but not yet forming a complete frame).
struct Rx {
    stream: UnixStream,
    src: usize,
    staging: Vec<u8>,
}

/// Read the 4-byte source-shard handshake a fresh connection leads with.
/// Bounded by a read timeout — the reader thread is shared by the whole
/// endpoint, so a connector that writes nothing must not freeze delta
/// delivery for the shard — and rejects ids outside `0..k` (a stray
/// connector must not index the window table).
fn handshake(mut stream: UnixStream, k: usize) -> Option<Rx> {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut id = [0u8; 4];
    stream.read_exact(&mut id).ok()?;
    let src = u32::from_le_bytes(id) as usize;
    if src >= k {
        return None;
    }
    let _ = stream.set_read_timeout(Some(Duration::from_millis(2)));
    Some(Rx { stream, src, staging: Vec::new() })
}

/// Move every complete frame at the front of `staging` into the endpoint
/// inbox, leaving a partial frame (if any) in place. Raw mode walks delta
/// frames (`len` at bytes 12..16); compressed mode walks envelopes (`len`
/// at bytes 4..8, [`SHADOW_RESET`] marking a body-less reset).
fn forward_frames(staging: &mut Vec<u8>, inbox: &Mutex<Vec<u8>>, compress: bool) {
    let mut end = 0usize;
    if compress {
        while staging.len() - end >= ENVELOPE_HEADER {
            let len = u32::from_le_bytes(staging[end + 4..end + 8].try_into().unwrap());
            let total = if len == SHADOW_RESET {
                ENVELOPE_HEADER
            } else {
                ENVELOPE_HEADER + len as usize
            };
            if staging.len() - end < total {
                break;
            }
            end += total;
        }
    } else {
        while staging.len() - end >= FRAME_HEADER {
            let len =
                u32::from_le_bytes(staging[end + 12..end + 16].try_into().unwrap()) as usize;
            if staging.len() - end < FRAME_HEADER + len {
                break;
            }
            end += FRAME_HEADER + len;
        }
    }
    if end > 0 {
        inbox.lock().unwrap().extend_from_slice(&staging[..end]);
        staging.drain(..end);
    }
}

/// The reader loop serving one shard endpoint (see the module docs): pure
/// byte mover — it never touches graph data, so it can outlive the
/// engine's scoped workers and be joined on transport drop.
fn reader_loop(
    listener: UnixListener,
    dst: usize,
    k: usize,
    inboxes: Arc<Vec<Mutex<Vec<u8>>>>,
    window: Arc<Vec<AtomicUsize>>,
    shutdown: Arc<AtomicBool>,
    compress: bool,
) {
    let _ = listener.set_nonblocking(true);
    let mut streams: Vec<Rx> = Vec::new();
    let mut buf = vec![0u8; 16 << 10];
    loop {
        // Fresh connections (initial set and reconnecting senders alike).
        while let Ok((stream, _)) = listener.accept() {
            if let Some(rx) = handshake(stream, k) {
                streams.push(rx);
            }
        }
        let mut moved = false;
        streams.retain_mut(|rx| match rx.stream.read(&mut buf) {
            // EOF: the sender shut the connection down; any torn frame
            // tail in staging dies with it (the sender resends whole
            // frames on its replacement connection).
            Ok(0) => false,
            Ok(n) => {
                // Land the bytes before shrinking the send window so the
                // window never under-counts what is still invisible to
                // `drain`.
                rx.staging.extend_from_slice(&buf[..n]);
                forward_frames(&mut rx.staging, &inboxes[dst], compress);
                let _ = window[rx.src * k + dst].fetch_update(
                    Ordering::AcqRel,
                    Ordering::Acquire,
                    |w| Some(w.saturating_sub(n)),
                );
                moved = true;
                true
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                true
            }
            Err(_) => false,
        });
        if streams.is_empty() && shutdown.load(Ordering::Acquire) {
            return;
        }
        if !moved {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

/// Ghost transport over Unix-domain sockets: one bound endpoint per shard
/// in a per-run temp directory, one delta connection plus one pull lane
/// per ordered shard pair, one reader thread per endpoint. Frames are
/// staged per connection and flushed with vectored writes; the
/// [`SocketTransport::compressed`] variant (`"socket-z"`) ships
/// shadow-diff frames instead of raw deltas. Borrows the shard view for
/// the duration of the run; dropping it joins the reader threads and
/// removes the socket directory.
pub struct SocketTransport<'g, V> {
    graph: &'g ShardedGraph<V>,
    k: usize,
    dir: PathBuf,
    compress: bool,
    /// Delta write halves, indexed `src * k + dst` (`None` on the
    /// diagonal and for single-shard graphs).
    conns: Vec<Option<Mutex<Connection>>>,
    /// Staged-bytes hint per connection, maintained under the connection
    /// lock: lets `flush_toward` and the drain path skip connections with
    /// nothing staged without taking their locks.
    staged_hint: Vec<AtomicUsize>,
    /// In-flight bytes per connection (staged or written, not yet landed
    /// in the destination inbox): the bounded send window.
    window: Arc<Vec<AtomicUsize>>,
    /// Per-destination inbox of complete delta frames (raw) or envelopes
    /// (compressed).
    inboxes: Arc<Vec<Mutex<Vec<u8>>>>,
    /// Compressed mode: receiver-side diff shadows per destination, keyed
    /// `(src_shard, vertex)` — one inbox mixes every source's lanes.
    rx_shadow: Vec<Mutex<HashMap<(u32, VertexId), Vec<u8>>>>,
    /// Pull lanes, indexed `requester * k + owner`.
    pulls: Vec<Option<Mutex<PullLane>>>,
    send_cap: usize,
    shutdown: Arc<AtomicBool>,
    readers: Vec<std::thread::JoinHandle<()>>,
    backpressure: AtomicU64,
    reconnects: AtomicU64,
    backoffs: AtomicU64,
    lane_timeouts: AtomicU64,
    pipelined: AtomicU64,
}

impl<'g, V> SocketTransport<'g, V> {
    /// Bind the endpoints, connect every shard pair, and spawn the reader
    /// threads, with the default send window and raw frames.
    pub fn new(graph: &'g ShardedGraph<V>) -> std::io::Result<SocketTransport<'g, V>> {
        SocketTransport::with_options(graph, DEFAULT_SEND_BUFFER, false)
    }

    /// Like [`SocketTransport::new`] with an explicit per-connection send
    /// window (clamped to at least 1 byte). Tiny windows are useful to
    /// exercise backpressure in tests.
    pub fn with_send_buffer(
        graph: &'g ShardedGraph<V>,
        send_cap: usize,
    ) -> std::io::Result<SocketTransport<'g, V>> {
        SocketTransport::with_options(graph, send_cap, false)
    }

    /// The `"socket-z"` variant: delta frames are shadow-diff compressed
    /// ([`super::encode_delta`]) inside `u32 src, u32 len` envelopes, with
    /// an in-band shadow-reset marker keeping reconnects sound. Pull
    /// frames stay raw.
    pub fn compressed(graph: &'g ShardedGraph<V>) -> std::io::Result<SocketTransport<'g, V>> {
        SocketTransport::with_options(graph, DEFAULT_SEND_BUFFER, true)
    }

    fn with_options(
        graph: &'g ShardedGraph<V>,
        send_cap: usize,
        compress: bool,
    ) -> std::io::Result<SocketTransport<'g, V>> {
        let k = graph.num_shards();
        let dir = next_socket_dir();
        // A stale dir from a crashed run (pid reuse) would fail the binds.
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let window: Arc<Vec<AtomicUsize>> =
            Arc::new((0..k * k).map(|_| AtomicUsize::new(0)).collect());
        let inboxes: Arc<Vec<Mutex<Vec<u8>>>> =
            Arc::new((0..k).map(|_| Mutex::new(Vec::new())).collect());
        let mut readers = Vec::new();
        if k > 1 {
            for dst in 0..k {
                let listener = UnixListener::bind(Self::endpoint(&dir, dst))?;
                let inboxes = Arc::clone(&inboxes);
                let window = Arc::clone(&window);
                let shutdown = Arc::clone(&shutdown);
                readers.push(
                    std::thread::Builder::new()
                        .name(format!("ghost-rx-{dst}"))
                        .spawn(move || {
                            reader_loop(listener, dst, k, inboxes, window, shutdown, compress)
                        })?,
                );
            }
        }
        let mut conns = Vec::with_capacity(k * k);
        let mut pulls = Vec::with_capacity(k * k);
        for a in 0..k {
            for b in 0..k {
                if a == b || k < 2 {
                    conns.push(None);
                    pulls.push(None);
                } else {
                    conns.push(Some(Mutex::new(Connection::open(
                        &Self::endpoint(&dir, b),
                        a as u32,
                        compress,
                    )?)));
                    let (near, far) = UnixStream::pair()?;
                    // A dead or severed peer must surface as a counted
                    // pull timeout, never hang the admitting worker:
                    // bound every lane read and write.
                    for s in [&near, &far] {
                        s.set_read_timeout(Some(PULL_IO_TIMEOUT))?;
                        s.set_write_timeout(Some(PULL_IO_TIMEOUT))?;
                    }
                    pulls.push(Some(Mutex::new(PullLane { near, far })));
                }
            }
        }
        Ok(SocketTransport {
            graph,
            k,
            dir,
            compress,
            conns,
            staged_hint: (0..k * k).map(|_| AtomicUsize::new(0)).collect(),
            window,
            inboxes,
            rx_shadow: (0..k).map(|_| Mutex::new(HashMap::new())).collect(),
            pulls,
            send_cap: send_cap.max(1),
            shutdown,
            readers,
            backpressure: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            backoffs: AtomicU64::new(0),
            lane_timeouts: AtomicU64::new(0),
            pipelined: AtomicU64::new(0),
        })
    }

    fn endpoint(dir: &Path, shard: usize) -> PathBuf {
        dir.join(format!("shard-{shard}.sock"))
    }

    /// The temp directory holding this transport's socket files (removed
    /// when the transport drops).
    pub fn socket_dir(&self) -> &Path {
        &self.dir
    }

    /// Reconnections performed after broken-pipe flushes (diagnostics).
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Pull requests that crossed a lane as part of a multi-request
    /// pipelined wave (diagnostics; see [`GhostTransport::pull_many`]).
    pub fn pulls_pipelined(&self) -> u64 {
        self.pipelined.load(Ordering::Relaxed)
    }

    /// Push every frame still staged toward `dst_shard` into the kernel.
    /// Senders are in-process, so the drain path calls this before
    /// sweeping the inbox — a staged frame must never outwait the drain
    /// that would apply it.
    fn flush_toward(&self, dst_shard: usize) {
        for src in 0..self.k {
            let idx = src * self.k + dst_shard;
            if self.staged_hint[idx].load(Ordering::Acquire) == 0 {
                continue;
            }
            let Some(conn) = &self.conns[idx] else { continue };
            let mut c = conn.lock().unwrap();
            if c.staged_bytes > 0 {
                c.flush(dst_shard, &self.window[idx], &self.reconnects, &self.backoffs);
            }
            self.staged_hint[idx].store(0, Ordering::Release);
        }
    }

    /// Fault hook: shut down the `src -> dst` delta connection's stream
    /// so the next flush trips the reconnect-with-backoff path. The
    /// endpoint stays bound, so the reconnect succeeds — this severs one
    /// connection, not the peer.
    pub fn sever_delta_connection(&self, src: usize, dst: usize) {
        if let Some(conn) = &self.conns[src * self.k + dst] {
            let conn = conn.lock().unwrap();
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
    }

    /// Fault hook: shut down both ends of the `requester -> owner` pull
    /// lane; subsequent pulls on the lane fail fast and are counted as
    /// pull timeouts instead of hanging the admitting worker.
    pub fn sever_pull_lane(&self, requester: usize, owner: usize) {
        if let Some(lane) = &self.pulls[requester * self.k + owner] {
            let lane = lane.lock().unwrap();
            let _ = lane.near.shutdown(std::net::Shutdown::Both);
            let _ = lane.far.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl<'g, V: VertexCodec + Clone + Send + Sync> SocketTransport<'g, V> {
    /// Compressed-mode drain: decode envelopes under **both** the inbox
    /// lock and the shadow lock — a diff body is only sound against the
    /// shadow state as of its position in the stream, so a concurrent
    /// drain of the same shard must not decode newer envelopes before
    /// these advance the shadows (the channel-z lane discipline).
    fn drain_compressed(&self, dst_shard: usize) -> DrainReceipt {
        let mut out = DrainReceipt::default();
        let mut inbox = self.inboxes[dst_shard].lock().unwrap();
        if inbox.is_empty() {
            return out;
        }
        let buf = std::mem::take(&mut *inbox);
        let mut shadows = self.rx_shadow[dst_shard].lock().unwrap();
        out.bytes = buf.len() as u64;
        let shard = self.graph.shard(dst_shard);
        let mut rest: &[u8] = &buf;
        let mut payload = Vec::new();
        while rest.len() >= ENVELOPE_HEADER {
            let src = u32::from_le_bytes(rest[..4].try_into().unwrap());
            let len = u32::from_le_bytes(rest[4..8].try_into().unwrap());
            if len == SHADOW_RESET {
                // In-band reset: the sender reconnected and will re-ship
                // its resend set raw; every shadow for it is void.
                shadows.retain(|&(s, _), _| s != src);
                rest = &rest[ENVELOPE_HEADER..];
                continue;
            }
            if rest.len() < ENVELOPE_HEADER + len as usize {
                debug_assert!(false, "torn envelope reached the inbox of shard {dst_shard}");
                break;
            }
            let body = &rest[ENVELOPE_HEADER..ENVELOPE_HEADER + len as usize];
            rest = &rest[ENVELOPE_HEADER + len as usize..];
            let Some((header, after)) = decode_header(body) else {
                debug_assert!(false, "corrupt envelope body on shard {dst_shard}");
                continue;
            };
            let key = (src, header.vertex);
            if decode_payload(&header, after, shadows.get(&key).map(|s| s.as_slice()), &mut payload)
                .is_none()
            {
                debug_assert!(false, "undecodable diff for vertex {} on {dst_shard}", header.vertex);
                continue;
            }
            // The shadow advances on EVERY frame — including ones
            // newest-wins rejects below — mirroring the sender's
            // per-encode advance, or the next diff desyncs.
            shadows
                .entry(key)
                .and_modify(|p| {
                    p.clear();
                    p.extend_from_slice(&payload);
                })
                .or_insert_with(|| payload.clone());
            let Some(value) = V::decode(&payload) else {
                debug_assert!(false, "codec round-trip failed for vertex {}", header.vertex);
                continue;
            };
            if let Some(entry) = shard.ghost_of(header.vertex) {
                if entry.store_versioned(&value, header.version) {
                    out.applied += 1;
                    crate::telemetry::instant(
                        crate::telemetry::EventKind::WireApply,
                        header.vertex as u64,
                        header.version,
                    );
                }
            }
        }
        debug_assert!(rest.is_empty(), "trailing bytes in the inbox of shard {dst_shard}");
        // `inbox` stays locked to here so the shadow advance above is
        // ordered against the reader's next append.
        drop(inbox);
        out
    }

    /// Owner+requester halves of one pull whose request frame already
    /// crossed the lane: read it at the owner end, serve the reply, move
    /// it back in lock-step chunks (the same thread plays both ends, so
    /// at most [`PULL_CHUNK`] reply bytes ever sit in a kernel buffer),
    /// and apply it. `Err` means the lane is down (timeout or sever); the
    /// caller counts it.
    fn finish_pull_exchange<'m>(
        &self,
        lane: &mut PullLane,
        dst_shard: usize,
        owner: usize,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> std::io::Result<PullReceipt> {
        let mut raw = [0u8; PullRequest::WIRE_LEN];
        lane.far.read_exact(&mut raw)?;
        let Some(reply) = super::serve_pull(&raw, master) else {
            debug_assert!(false, "corrupt pull request on {dst_shard}->{owner}");
            return Ok(PullReceipt { applied: false, served: true, bytes: 0 });
        };
        let mut got = vec![0u8; reply.len()];
        let mut off = 0usize;
        while off < reply.len() {
            let end = (off + PULL_CHUNK).min(reply.len());
            lane.far.write_all(&reply[off..end])?;
            lane.near.read_exact(&mut got[off..end])?;
            off = end;
        }
        // Requester side: decode the reply and apply it (newest wins).
        let Some(applied) = super::apply_pull_reply(self.graph, dst_shard, &got) else {
            debug_assert!(false, "corrupt pull reply on {owner}->{dst_shard}");
            return Ok(PullReceipt { applied: false, served: true, bytes: reply.len() as u64 });
        };
        Ok(PullReceipt { applied, served: true, bytes: reply.len() as u64 })
    }
}

impl<V> Drop for SocketTransport<'_, V> {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        for conn in self.conns.iter().flatten() {
            let conn = conn.lock().unwrap_or_else(|p| p.into_inner());
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

impl<V: VertexCodec + Clone + Send + Sync> GhostTransport<V> for SocketTransport<'_, V> {
    fn name(&self) -> &'static str {
        if self.compress {
            "socket-z"
        } else {
            "socket"
        }
    }

    fn send(&self, src_shard: usize, vertex: VertexId, version: u64, data: &V) -> SendReceipt {
        let sites = self.graph.replicas_of(vertex);
        if sites.is_empty() {
            return SendReceipt::default();
        }
        crate::telemetry::instant(
            crate::telemetry::EventKind::WireSend,
            vertex as u64,
            version,
        );
        // Encode once per send, not per replica site.
        let mut payload = Vec::new();
        let mut frame = Vec::new();
        if self.compress {
            data.encode(&mut payload);
        } else {
            let delta = GhostDelta::from_vertex(vertex, version, data);
            frame.reserve(delta.wire_len());
            delta.encode_into(&mut frame);
        }
        // Window-admission estimate: the compressed frame size depends on
        // the per-lane shadow, but is bounded by envelope + varint header
        // + raw payload.
        let est = if self.compress { ENVELOPE_HEADER + payload.len() + 21 } else { frame.len() };
        let mut bytes = 0u64;
        for &(s, gi) in sites {
            let dst = s as usize;
            // Advance the pending slot before the bytes leave so a
            // staleness probe never sees an in-flight version it cannot
            // account for.
            self.graph.shard(dst).ghost(gi as usize).note_pending(version);
            let idx = src_shard * self.k + dst;
            let Some(conn) = &self.conns[idx] else { continue };
            // Bounded send window: block the flush (backpressure) until
            // the reader lands enough in-flight bytes. An empty window
            // always admits the frame, so frames larger than the whole
            // window still make progress. The window is a *soft* bound:
            // the check-then-add is racy across workers of one shard
            // (overshoot of one frame per concurrent sender), and the
            // stall is time-bounded so a reconnect-skewed count can delay
            // a sender but never livelock it.
            let window = &self.window[idx];
            let mut stalled = false;
            // The stall-span clock starts only once the sender actually
            // stalls — the unstalled fast path reads no clock.
            let mut stall_span = crate::telemetry::SPAN_OFF;
            let mut spins = 0u32;
            loop {
                let inflight = window.load(Ordering::Acquire);
                if inflight == 0 || inflight + est <= self.send_cap {
                    break;
                }
                if !stalled {
                    stalled = true;
                    self.backpressure.fetch_add(1, Ordering::Relaxed);
                    stall_span = crate::telemetry::span_start();
                }
                // The window only shrinks once staged bytes reach the
                // kernel and land at the reader: flush our own staged
                // queue from inside the stall, or a sender could block
                // forever on frames it itself staged.
                if let Ok(mut c) = conn.try_lock() {
                    if c.staged_bytes > 0 {
                        c.flush(dst, window, &self.reconnects, &self.backoffs);
                        self.staged_hint[idx].store(0, Ordering::Release);
                    }
                }
                spins += 1;
                if spins > STALL_ITERS_MAX {
                    break;
                }
                if spins < 64 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
            if stalled {
                crate::telemetry::span_end(
                    crate::telemetry::EventKind::Backpressure,
                    stall_span,
                    vertex as u64,
                    dst as u64,
                );
            }
            let mut c = conn.lock().unwrap();
            let n = if self.compress {
                c.stage_compressed(vertex, version, &payload)
            } else {
                let n = frame.len();
                c.stage(frame.clone());
                n
            };
            window.fetch_add(n, Ordering::AcqRel);
            if c.staged_bytes >= STAGE_MAX_BYTES || c.staged.len() >= STAGE_MAX_FRAMES {
                c.flush(dst, window, &self.reconnects, &self.backoffs);
                self.staged_hint[idx].store(0, Ordering::Release);
            } else {
                self.staged_hint[idx].store(c.staged_bytes, Ordering::Release);
            }
            bytes += n as u64;
        }
        SendReceipt { replicas_now: 0, bytes }
    }

    fn drain(&self, dst_shard: usize) -> DrainReceipt {
        let mut out = DrainReceipt::default();
        if self.k < 2 {
            return out;
        }
        // Senders are in-process: staged frames bound for this shard must
        // not outwait the drain that would apply them.
        self.flush_toward(dst_shard);
        if self.compress {
            return self.drain_compressed(dst_shard);
        }
        let buf = {
            let mut q = self.inboxes[dst_shard].lock().unwrap();
            std::mem::take(&mut *q)
        };
        if buf.is_empty() {
            return out;
        }
        out.bytes = buf.len() as u64;
        let shard = self.graph.shard(dst_shard);
        let mut r = ByteReader::new(&buf);
        while !r.is_empty() {
            let Some(delta) = GhostDelta::decode_from(&mut r) else {
                debug_assert!(false, "torn frame reached the inbox of shard {dst_shard}");
                break;
            };
            let Some(value) = delta.decode_vertex::<V>() else {
                debug_assert!(false, "codec round-trip failed for vertex {}", delta.vertex);
                continue;
            };
            if let Some(entry) = shard.ghost_of(delta.vertex) {
                if entry.store_versioned(&value, delta.version) {
                    out.applied += 1;
                    crate::telemetry::instant(
                        crate::telemetry::EventKind::WireApply,
                        delta.vertex as u64,
                        delta.version,
                    );
                }
            }
        }
        out
    }

    fn pull<'m>(
        &self,
        dst_shard: usize,
        req: PullRequest,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> PullReceipt {
        let owner = self.graph.owner_of(req.vertex);
        let Some(lane) = &self.pulls[dst_shard * self.k + owner] else {
            return PullReceipt::default();
        };
        let mut lane = lane.lock().unwrap();
        // Requester -> owner: the request frame crosses the socket. Any
        // lane IO failure — timeout against a dead peer, or a severed
        // lane's broken pipe — fails the pull cleanly and is counted; the
        // engine's scope-admission retry loop owns recovery.
        let mut frame = Vec::with_capacity(PullRequest::WIRE_LEN);
        req.encode_into(&mut frame);
        if lane.near.write_all(&frame).is_err() {
            self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
            return PullReceipt::default();
        }
        match self.finish_pull_exchange(&mut lane, dst_shard, owner, master) {
            Ok(mut r) => {
                r.bytes += PullRequest::WIRE_LEN as u64;
                r
            }
            Err(_) => {
                self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
                PullReceipt::default()
            }
        }
    }

    fn pull_many<'m>(
        &self,
        dst_shard: usize,
        reqs: &[PullRequest],
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> Vec<PullReceipt> {
        let mut receipts = vec![PullReceipt::default(); reqs.len()];
        if self.k < 2 {
            return receipts;
        }
        let mut by_owner: Vec<Vec<usize>> = vec![Vec::new(); self.k];
        for (i, req) in reqs.iter().enumerate() {
            let owner = self.graph.owner_of(req.vertex);
            if owner != dst_shard {
                by_owner[owner].push(i);
            }
        }
        for (owner, idxs) in by_owner.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let Some(lane) = &self.pulls[dst_shard * self.k + owner] else { continue };
            let mut lane = lane.lock().unwrap();
            'waves: for wave in idxs.chunks(PULL_WAVE_MAX) {
                // Phase 1: every request frame in the wave crosses the
                // lane in one write before the first reply is served — N
                // pulls pay one syscall and one lane acquisition.
                let mut batch = Vec::with_capacity(wave.len() * PullRequest::WIRE_LEN);
                for &i in wave {
                    reqs[i].encode_into(&mut batch);
                }
                if lane.near.write_all(&batch).is_err() {
                    self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
                    break 'waves;
                }
                if wave.len() > 1 {
                    self.pipelined.fetch_add(wave.len() as u64, Ordering::Relaxed);
                }
                // Phase 2: serve, return, and apply the replies in
                // request order. A lane failure abandons the rest of this
                // owner's requests (default receipts); the engine's
                // per-ghost retry loop owns recovery.
                for &i in wave {
                    match self.finish_pull_exchange(&mut lane, dst_shard, owner, master) {
                        Ok(mut r) => {
                            r.bytes += PullRequest::WIRE_LEN as u64;
                            receipts[i] = r;
                        }
                        Err(_) => {
                            self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
                            break 'waves;
                        }
                    }
                }
            }
        }
        receipts
    }

    fn queued_bytes(&self, dst_shard: usize) -> u64 {
        let mut total = self.inboxes[dst_shard].lock().unwrap().len() as u64;
        for src in 0..self.k {
            total += self.window[src * self.k + dst_shard].load(Ordering::Acquire) as u64;
        }
        total
    }

    fn finalize(&self) {
        // Push every staged frame into the kernel first — the window
        // below cannot drain bytes that never left a staging queue.
        for dst in 0..self.k {
            self.flush_toward(dst);
        }
        // Wait (bounded, ~10s) until every written byte has landed in an
        // inbox: senders only write whole frames, so a zero window means
        // the inboxes hold the complete, frame-aligned stream. On timeout
        // — overloaded machine, or a reconnect-skewed window count — warn
        // loudly rather than fail silently: the caller's final drain may
        // miss in-flight deltas.
        for _ in 0..100_000 {
            let inflight: usize =
                self.window.iter().map(|w| w.load(Ordering::Acquire)).sum();
            if inflight == 0 {
                return;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        let inflight: usize = self.window.iter().map(|w| w.load(Ordering::Acquire)).sum();
        eprintln!(
            "graphlab socket transport: finalize timed out with {inflight} bytes \
             in flight; the final drain may miss ghost deltas"
        );
        debug_assert!(false, "socket transport finalize timed out with bytes in flight");
    }

    fn backpressure_stalls(&self) -> u64 {
        self.backpressure.load(Ordering::Relaxed)
    }

    fn pull_timeouts(&self) -> u64 {
        self.lane_timeouts.load(Ordering::Relaxed)
    }

    fn reconnect_backoffs(&self) -> u64 {
        self.backoffs.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DataGraph, GraphBuilder};

    fn chain(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(i as u64);
        }
        for i in 0..n - 1 {
            b.add_undirected(i as u32, i as u32 + 1, (), ());
        }
        b.build()
    }

    /// A bipartite cross: edges (i, n/2 + i). However the partitioner
    /// splits it, two shards end up with several boundary vertices each —
    /// the shape the pull-pipelining test needs.
    fn cross(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(i as u64);
        }
        let h = n / 2;
        for i in 0..h {
            b.add_undirected(i as u32, (h + i) as u32, (), ());
        }
        b.build()
    }

    /// Poll `drain` until `want` applies land (bounded): flushes are
    /// asynchronous to the reader thread, so tests wait rather than race.
    fn drain_until<V: VertexCodec + Clone + Send + Sync>(
        t: &SocketTransport<'_, V>,
        dst: usize,
        want: u64,
    ) -> u64 {
        let mut applied = 0;
        for _ in 0..10_000 {
            applied += GhostTransport::drain(t, dst).applied;
            if applied >= want {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        applied
    }

    #[test]
    fn deltas_cross_the_socket_and_apply_on_drain() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        assert!(t.socket_dir().exists(), "socket files live in the temp dir");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        let r = GhostTransport::send(&t, owner, v, 4, &777u64);
        assert!(r.bytes > 0, "socket backend really ships bytes");
        assert_eq!(r.replicas_now, 0, "socket applies at drain, not send");
        assert_eq!(entry.pending_version(), 4, "in-flight version visible");
        GhostTransport::finalize(&t);
        let d = GhostTransport::drain(&t, dst as usize);
        assert_eq!(d.applied, 1);
        assert_eq!(d.bytes, r.bytes, "every shipped byte consumed");
        assert_eq!(entry.read(), 777, "payload round-tripped the socket");
        assert_eq!(entry.version(), 4);
        assert_eq!(GhostTransport::queued_bytes(&t, dst as usize), 0);

        let dir = t.socket_dir().to_path_buf();
        drop(t);
        assert!(!dir.exists(), "socket files cleaned up on drop");
    }

    #[test]
    fn severed_delta_connection_reconnects_with_backoff() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);
        t.sever_delta_connection(owner, dst as usize);
        let r = GhostTransport::send(&t, owner, v, 2, &555u64);
        assert!(r.bytes > 0);
        // The send only *staged* the frame; the drain's flush hits the
        // severed stream and must reconnect. Poll the drain (bounded)
        // rather than finalize — the torn write skews the window
        // accounting, which finalize only tolerates noisily.
        assert_eq!(drain_until(&t, dst as usize, 1), 1, "severed frame resent and applied");
        assert!(t.reconnects() >= 1, "a broken pipe must reconnect");
        assert!(
            GhostTransport::reconnect_backoffs(&t) >= 1,
            "each reconnect attempt waits one counted backoff"
        );
        assert_eq!(entry.read(), 555);
        assert_eq!(entry.version(), 2);
    }

    #[test]
    fn severed_pull_lane_fails_fast_and_counts_a_timeout() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, _gi) = sg.replicas_of(v)[0];
        t.sever_pull_lane(dst as usize, owner);
        let master = 999u64;
        let r = GhostTransport::pull(
            &t,
            dst as usize,
            PullRequest { vertex: v, min_version: 1 },
            &|u| {
                assert_eq!(u, v);
                (&master, 1)
            },
        );
        assert!(!r.applied && !r.served, "a severed lane fails the pull cleanly");
        assert_eq!(GhostTransport::pull_timeouts(&t), 1, "the failure is counted");
    }

    #[test]
    fn partial_frames_never_reach_the_inbox() {
        let inbox = Mutex::new(Vec::new());
        let d = GhostDelta::from_vertex(3, 9, &1234u64);
        let mut frame = Vec::new();
        d.encode_into(&mut frame);
        // Deliver the frame in three fragments: nothing forwards until the
        // final fragment completes it.
        let mut staging = Vec::new();
        staging.extend_from_slice(&frame[..10]);
        forward_frames(&mut staging, &inbox, false);
        assert!(inbox.lock().unwrap().is_empty());
        staging.extend_from_slice(&frame[10..frame.len() - 1]);
        forward_frames(&mut staging, &inbox, false);
        assert!(inbox.lock().unwrap().is_empty());
        staging.extend_from_slice(&frame[frame.len() - 1..]);
        forward_frames(&mut staging, &inbox, false);
        assert_eq!(*inbox.lock().unwrap(), frame);
        assert!(staging.is_empty());
    }

    #[test]
    fn partial_envelopes_never_reach_the_inbox() {
        let inbox = Mutex::new(Vec::new());
        // A reset marker followed by one compressed envelope.
        let mut stream = Vec::new();
        put_u32(&mut stream, 1);
        put_u32(&mut stream, SHADOW_RESET);
        let at = stream.len();
        put_u32(&mut stream, 1);
        put_u32(&mut stream, 0);
        let payload = [7u8; 24];
        let body_len = encode_delta(3, 9, &payload, None, &mut stream);
        stream[at + 4..at + 8].copy_from_slice(&(body_len as u32).to_le_bytes());
        // Cut inside the second envelope's body: only the reset (a
        // complete, body-less envelope) may forward.
        let cut = at + ENVELOPE_HEADER + 2;
        let mut staging = Vec::new();
        staging.extend_from_slice(&stream[..cut]);
        forward_frames(&mut staging, &inbox, true);
        assert_eq!(inbox.lock().unwrap().len(), ENVELOPE_HEADER, "only the reset forwards");
        staging.extend_from_slice(&stream[cut..]);
        forward_frames(&mut staging, &inbox, true);
        assert_eq!(*inbox.lock().unwrap(), stream);
        assert!(staging.is_empty());
    }

    #[test]
    fn socket_z_round_trips_and_shrinks_repeat_frames() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::compressed(&sg).expect("socket setup");
        assert_eq!(GhostTransport::name(&t), "socket-z");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        // First ship is raw (no shadow yet); the re-ship of an identical
        // payload diffs down to a few bytes.
        let r1 = GhostTransport::send(&t, owner, v, 1, &777u64);
        let r2 = GhostTransport::send(&t, owner, v, 2, &777u64);
        assert!(r1.bytes > 0 && r2.bytes > 0);
        assert!(
            r2.bytes < r1.bytes,
            "unchanged payload must diff smaller ({} vs {})",
            r2.bytes,
            r1.bytes
        );
        let raw_wire = GhostDelta::from_vertex(v, 2, &777u64).wire_len() as u64;
        assert!(r2.bytes < raw_wire, "diff frame beats the raw wire frame");
        GhostTransport::finalize(&t);
        let d = GhostTransport::drain(&t, dst as usize);
        assert_eq!(d.applied, 2, "both versions apply in order");
        assert_eq!(d.bytes, r1.bytes + r2.bytes, "every shipped byte consumed");
        assert_eq!(entry.read(), 777);
        assert_eq!(entry.version(), 2);
        assert_eq!(GhostTransport::queued_bytes(&t, dst as usize), 0);
    }

    #[test]
    fn socket_z_reconnect_resets_diff_shadows() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::compressed(&sg).expect("socket setup");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        // Establish diff shadows on both ends, then kill the connection:
        // the resend must cross as reset + raw and still reconstruct.
        let _ = GhostTransport::send(&t, owner, v, 1, &111u64);
        GhostTransport::finalize(&t);
        assert_eq!(drain_until(&t, dst as usize, 1), 1);
        assert_eq!(entry.read(), 111);
        t.sever_delta_connection(owner, dst as usize);
        let _ = GhostTransport::send(&t, owner, v, 2, &222u64);
        assert_eq!(drain_until(&t, dst as usize, 1), 1, "resent frame applies");
        assert!(t.reconnects() >= 1, "the severed flush reconnected");
        assert_eq!(entry.read(), 222, "payload reconstructed after the shadow reset");
        assert_eq!(entry.version(), 2);
    }

    #[test]
    fn pull_many_pipelines_requests_toward_each_owner() {
        let mut g = cross(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        let masters: Vec<u64> = (0..8u64).map(|i| 1000 + i).collect();
        let mut tested = false;
        for dst in 0..2usize {
            let reqs: Vec<PullRequest> = (0..8u32)
                .filter(|&v| {
                    sg.owner_of(v) != dst
                        && sg.replicas_of(v).iter().any(|&(s, _)| s as usize == dst)
                })
                .map(|v| PullRequest { vertex: v, min_version: 1 })
                .collect();
            if reqs.len() < 2 {
                continue;
            }
            tested = true;
            let before = t.pulls_pipelined();
            let receipts =
                GhostTransport::pull_many(&t, dst, &reqs, &|u| (&masters[u as usize], 1));
            assert_eq!(receipts.len(), reqs.len());
            for (req, r) in reqs.iter().zip(&receipts) {
                assert!(r.served, "vertex {} served", req.vertex);
                assert!(r.applied, "vertex {} applied", req.vertex);
                assert!(r.bytes > PullRequest::WIRE_LEN as u64);
                let (s, gi) = *sg
                    .replicas_of(req.vertex)
                    .iter()
                    .find(|&&(s, _)| s as usize == dst)
                    .unwrap();
                let entry = sg.shard(s as usize).ghost(gi as usize);
                assert_eq!(entry.read(), masters[req.vertex as usize]);
            }
            assert!(
                t.pulls_pipelined() - before >= reqs.len() as u64,
                "more than one pull was in flight on the lane"
            );
        }
        assert!(tested, "the cross graph must yield a shard with >= 2 remote ghosts");
    }
}
