//! The **Unix-domain-socket backend**: ghost deltas and staleness pulls
//! moved as real kernel-socket bytes between per-shard endpoints — the
//! in-process rehearsal of a true multi-process deployment, std-only, no
//! filesystem footprint beyond a per-run temp directory of socket files
//! (removed on drop, so parallel test binaries never collide).
//!
//! # Wire format
//!
//! Exactly the parent `transport` module's two frame kinds, byte-for-byte:
//!
//! * **delta frames** (`u32 vertex, u64 version, u32 len, payload`) flow
//!   over one `UnixStream` per ordered shard pair into the destination
//!   endpoint; replicas apply **newest-wins** at [`GhostTransport::drain`]
//!   (`GhostEntry::store_versioned`), so frames reordered across
//!   connections — or re-sent after a reconnect — are harmless;
//! * **pull frames** (`u32 vertex, u64 min_version`, fixed
//!   [`PullRequest::WIRE_LEN`] bytes) cross a dedicated request/reply
//!   socketpair lane per ordered shard pair; the reply is an ordinary
//!   delta frame carrying the owner's current master data.
//!
//! # Topology & delivery
//!
//! Each shard binds one endpoint (`shard-<i>.sock`) in a unique temp
//! directory; every other shard connects to it and identifies itself with
//! a 4-byte handshake. **One reader thread serves each endpoint**: it
//! accepts connections (including re-connections), moves received bytes
//! into per-stream staging buffers, and forwards only *complete* frames
//! to the endpoint inbox — a torn write from a dropped connection can
//! never corrupt the frame stream, and the sender's retry after a
//! reconnect lands cleanly. Workers apply inboxed frames on their normal
//! [`GhostTransport::drain`] cadence.
//!
//! # Backpressure & reconnect
//!
//! Every connection has a **bounded send window** (default
//! [`DEFAULT_SEND_BUFFER`] bytes of in-flight data, configurable down to
//! bytes for tests): a send that would overflow it blocks — stalling the
//! engine's batcher flush, which is the intended flow control — until the
//! reader lands enough bytes, and each stalled send increments the
//! [`GhostTransport::backpressure_stalls`] counter. A frame larger than
//! the whole window is sent alone once the window is empty, so progress
//! is always possible. Writes that fail with a broken pipe reconnect to
//! the endpoint (fresh handshake) under **capped exponential backoff** —
//! a deterministic 2, 4, 8, …, 64 ms schedule, each wait counted in
//! [`GhostTransport::reconnect_backoffs`] — and resend the entire frame;
//! exhausting the attempt budget panics with the vertex and shard pair in
//! the message, never drops the delta silently. Pull lanes carry read and
//! write timeouts, so a crashed peer surfaces as a counted
//! [`GhostTransport::pull_timeouts`] failure (retried by the engine's
//! scope-admission backoff loop) instead of hanging the admitting worker.
//! [`SocketTransport::sever_delta_connection`] and
//! [`SocketTransport::sever_pull_lane`] let fault tests trip both paths
//! on demand.

use super::{
    ByteReader, DrainReceipt, GhostDelta, GhostTransport, PullReceipt, PullRequest, SendReceipt,
    VertexCodec,
};
use crate::graph::{ShardedGraph, VertexId};
use std::io::{ErrorKind, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Default per-connection bounded send window, in bytes of in-flight
/// (sent but not yet received) data.
pub const DEFAULT_SEND_BUFFER: usize = 1 << 20;

/// Delta frame header size: `u32 vertex + u64 version + u32 payload_len`.
const FRAME_HEADER: usize = 16;

/// Chunk size for the lock-step pull exchange: the requester thread plays
/// both ends of the lane, so no more than this many reply bytes are ever
/// in a kernel buffer — the exchange can never deadlock on buffer space.
const PULL_CHUNK: usize = 16 << 10;

/// How many reconnect attempts a broken-pipe send gets before giving up
/// and panicking with the vertex/shard context.
const RECONNECT_ATTEMPTS_MAX: u32 = 8;

/// Ceiling of the reconnect backoff schedule: waits double per attempt
/// (2, 4, 8, … ms) and cap here. Deterministic — no wall-clock jitter.
const RECONNECT_BACKOFF_CAP_MS: u64 = 64;

/// Read/write timeout on pull-lane sockets: a crashed or severed peer
/// fails the exchange (counted as a pull timeout) instead of hanging the
/// admitting worker indefinitely.
const PULL_IO_TIMEOUT: Duration = Duration::from_millis(500);

/// Upper bound on one send's backpressure stall (64 yields, then 50µs
/// sleeps — roughly one second). Keeps the soft window bound from ever
/// livelocking a sender if reconnect-torn accounting leaks the window
/// shut.
const STALL_ITERS_MAX: u32 = 20_000;

/// A unique socket directory per transport instance: process id plus an
/// in-process sequence number, so parallel test binaries (and parallel
/// tests within one binary) never collide on socket paths.
fn next_socket_dir() -> PathBuf {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("graphlab-sock-{}-{seq}", std::process::id()))
}

/// Write half of one `src -> dst` delta connection.
struct Connection {
    stream: UnixStream,
    endpoint: PathBuf,
    src: u32,
}

impl Connection {
    fn open(endpoint: &Path, src: u32) -> std::io::Result<Connection> {
        let mut stream = UnixStream::connect(endpoint)?;
        stream.write_all(&src.to_le_bytes())?;
        Ok(Connection { stream, endpoint: endpoint.to_path_buf(), src })
    }

    /// `write_all` with reconnect-on-broken-pipe: the reader forwards only
    /// complete frames, so a torn partial write dies with the old stream
    /// and the whole frame is resent on the fresh connection, after a
    /// capped-exponential backoff wait (2, 4, 8, …, capped at
    /// [`RECONNECT_BACKOFF_CAP_MS`] ms — a deterministic schedule, each
    /// wait counted in `backoffs`). Exhausting the attempt budget panics
    /// with the vertex and shard pair, never drops the delta silently.
    /// Each retry re-adds the frame to `window` — the reader decrements
    /// every raw byte it receives (including torn tails), so without the
    /// re-add a resend could drive the window negative and make
    /// `finalize` return while bytes are still in flight. `write_all`
    /// cannot report partial progress, so the accounting errs toward a
    /// bounded *over*-count per reconnect; the send path's stall loop is
    /// time-bounded for exactly this reason.
    #[allow(clippy::too_many_arguments)]
    fn send(
        &mut self,
        frame: &[u8],
        vertex: VertexId,
        dst: usize,
        window: &AtomicUsize,
        reconnects: &AtomicU64,
        backoffs: &AtomicU64,
    ) {
        let mut attempt = 0u32;
        loop {
            match self.stream.write_all(frame) {
                Ok(()) => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::BrokenPipe
                            | ErrorKind::ConnectionReset
                            | ErrorKind::ConnectionAborted
                            | ErrorKind::NotConnected
                            | ErrorKind::WriteZero
                    ) =>
                {
                    attempt += 1;
                    assert!(
                        attempt <= RECONNECT_ATTEMPTS_MAX,
                        "ghost delta for vertex {vertex} (shard {src} -> {dst}) to {:?} \
                         failed after {RECONNECT_ATTEMPTS_MAX} reconnect attempts: {e}",
                        self.endpoint,
                        src = self.src,
                    );
                    reconnects.fetch_add(1, Ordering::Relaxed);
                    backoffs.fetch_add(1, Ordering::Relaxed);
                    crate::telemetry::instant(
                        crate::telemetry::EventKind::SocketReconnect,
                        vertex as u64,
                        attempt as u64,
                    );
                    let wait = (1u64 << attempt).min(RECONNECT_BACKOFF_CAP_MS);
                    std::thread::sleep(Duration::from_millis(wait));
                    if let Ok(fresh) = Connection::open(&self.endpoint, self.src) {
                        self.stream = fresh.stream;
                    }
                    window.fetch_add(frame.len(), Ordering::AcqRel);
                }
                Err(e) => panic!(
                    "ghost delta for vertex {vertex} (shard {} -> {dst}) to {:?} failed: {e}",
                    self.src, self.endpoint
                ),
            }
        }
    }
}

/// The request/reply socketpair lane one ordered shard pair uses for
/// staleness pulls. `near` is the requester's end, `far` the owner's.
struct PullLane {
    near: UnixStream,
    far: UnixStream,
}

/// One accepted inbound stream at an endpoint, with its frame-staging
/// buffer (bytes received but not yet forming a complete frame).
struct Rx {
    stream: UnixStream,
    src: usize,
    staging: Vec<u8>,
}

/// Read the 4-byte source-shard handshake a fresh connection leads with.
/// Bounded by a read timeout — the reader thread is shared by the whole
/// endpoint, so a connector that writes nothing must not freeze delta
/// delivery for the shard — and rejects ids outside `0..k` (a stray
/// connector must not index the window table).
fn handshake(mut stream: UnixStream, k: usize) -> Option<Rx> {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut id = [0u8; 4];
    stream.read_exact(&mut id).ok()?;
    let src = u32::from_le_bytes(id) as usize;
    if src >= k {
        return None;
    }
    let _ = stream.set_read_timeout(Some(Duration::from_millis(2)));
    Some(Rx { stream, src, staging: Vec::new() })
}

/// Move every complete delta frame at the front of `staging` into the
/// endpoint inbox, leaving a partial frame (if any) in place.
fn forward_frames(staging: &mut Vec<u8>, inbox: &Mutex<Vec<u8>>) {
    let mut end = 0usize;
    while staging.len() - end >= FRAME_HEADER {
        let len =
            u32::from_le_bytes(staging[end + 12..end + 16].try_into().unwrap()) as usize;
        if staging.len() - end < FRAME_HEADER + len {
            break;
        }
        end += FRAME_HEADER + len;
    }
    if end > 0 {
        inbox.lock().unwrap().extend_from_slice(&staging[..end]);
        staging.drain(..end);
    }
}

/// The reader loop serving one shard endpoint (see the module docs): pure
/// byte mover — it never touches graph data, so it can outlive the
/// engine's scoped workers and be joined on transport drop.
fn reader_loop(
    listener: UnixListener,
    dst: usize,
    k: usize,
    inboxes: Arc<Vec<Mutex<Vec<u8>>>>,
    window: Arc<Vec<AtomicUsize>>,
    shutdown: Arc<AtomicBool>,
) {
    let _ = listener.set_nonblocking(true);
    let mut streams: Vec<Rx> = Vec::new();
    let mut buf = vec![0u8; 16 << 10];
    loop {
        // Fresh connections (initial set and reconnecting senders alike).
        while let Ok((stream, _)) = listener.accept() {
            if let Some(rx) = handshake(stream, k) {
                streams.push(rx);
            }
        }
        let mut moved = false;
        streams.retain_mut(|rx| match rx.stream.read(&mut buf) {
            // EOF: the sender shut the connection down; any torn frame
            // tail in staging dies with it (the sender resends whole
            // frames on its replacement connection).
            Ok(0) => false,
            Ok(n) => {
                // Land the bytes before shrinking the send window so the
                // window never under-counts what is still invisible to
                // `drain`.
                rx.staging.extend_from_slice(&buf[..n]);
                forward_frames(&mut rx.staging, &inboxes[dst]);
                let _ = window[rx.src * k + dst].fetch_update(
                    Ordering::AcqRel,
                    Ordering::Acquire,
                    |w| Some(w.saturating_sub(n)),
                );
                moved = true;
                true
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                true
            }
            Err(_) => false,
        });
        if streams.is_empty() && shutdown.load(Ordering::Acquire) {
            return;
        }
        if !moved {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

/// Ghost transport over Unix-domain sockets: one bound endpoint per shard
/// in a per-run temp directory, one delta connection plus one pull lane
/// per ordered shard pair, one reader thread per endpoint. Borrows the
/// shard view for the duration of the run; dropping it joins the reader
/// threads and removes the socket directory.
pub struct SocketTransport<'g, V> {
    graph: &'g ShardedGraph<V>,
    k: usize,
    dir: PathBuf,
    /// Delta write halves, indexed `src * k + dst` (`None` on the
    /// diagonal and for single-shard graphs).
    conns: Vec<Option<Mutex<Connection>>>,
    /// In-flight bytes per connection (written, not yet landed in the
    /// destination inbox): the bounded send window.
    window: Arc<Vec<AtomicUsize>>,
    /// Per-destination inbox of complete delta frames.
    inboxes: Arc<Vec<Mutex<Vec<u8>>>>,
    /// Pull lanes, indexed `requester * k + owner`.
    pulls: Vec<Option<Mutex<PullLane>>>,
    send_cap: usize,
    shutdown: Arc<AtomicBool>,
    readers: Vec<std::thread::JoinHandle<()>>,
    backpressure: AtomicU64,
    reconnects: AtomicU64,
    backoffs: AtomicU64,
    lane_timeouts: AtomicU64,
}

impl<'g, V> SocketTransport<'g, V> {
    /// Bind the endpoints, connect every shard pair, and spawn the reader
    /// threads, with the default send window.
    pub fn new(graph: &'g ShardedGraph<V>) -> std::io::Result<SocketTransport<'g, V>> {
        SocketTransport::with_send_buffer(graph, DEFAULT_SEND_BUFFER)
    }

    /// Like [`SocketTransport::new`] with an explicit per-connection send
    /// window (clamped to at least 1 byte). Tiny windows are useful to
    /// exercise backpressure in tests.
    pub fn with_send_buffer(
        graph: &'g ShardedGraph<V>,
        send_cap: usize,
    ) -> std::io::Result<SocketTransport<'g, V>> {
        let k = graph.num_shards();
        let dir = next_socket_dir();
        // A stale dir from a crashed run (pid reuse) would fail the binds.
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let window: Arc<Vec<AtomicUsize>> =
            Arc::new((0..k * k).map(|_| AtomicUsize::new(0)).collect());
        let inboxes: Arc<Vec<Mutex<Vec<u8>>>> =
            Arc::new((0..k).map(|_| Mutex::new(Vec::new())).collect());
        let mut readers = Vec::new();
        if k > 1 {
            for dst in 0..k {
                let listener = UnixListener::bind(Self::endpoint(&dir, dst))?;
                let inboxes = Arc::clone(&inboxes);
                let window = Arc::clone(&window);
                let shutdown = Arc::clone(&shutdown);
                readers.push(
                    std::thread::Builder::new()
                        .name(format!("ghost-rx-{dst}"))
                        .spawn(move || {
                            reader_loop(listener, dst, k, inboxes, window, shutdown)
                        })?,
                );
            }
        }
        let mut conns = Vec::with_capacity(k * k);
        let mut pulls = Vec::with_capacity(k * k);
        for a in 0..k {
            for b in 0..k {
                if a == b || k < 2 {
                    conns.push(None);
                    pulls.push(None);
                } else {
                    conns.push(Some(Mutex::new(Connection::open(
                        &Self::endpoint(&dir, b),
                        a as u32,
                    )?)));
                    let (near, far) = UnixStream::pair()?;
                    // A dead or severed peer must surface as a counted
                    // pull timeout, never hang the admitting worker:
                    // bound every lane read and write.
                    for s in [&near, &far] {
                        s.set_read_timeout(Some(PULL_IO_TIMEOUT))?;
                        s.set_write_timeout(Some(PULL_IO_TIMEOUT))?;
                    }
                    pulls.push(Some(Mutex::new(PullLane { near, far })));
                }
            }
        }
        Ok(SocketTransport {
            graph,
            k,
            dir,
            conns,
            window,
            inboxes,
            pulls,
            send_cap: send_cap.max(1),
            shutdown,
            readers,
            backpressure: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            backoffs: AtomicU64::new(0),
            lane_timeouts: AtomicU64::new(0),
        })
    }

    fn endpoint(dir: &Path, shard: usize) -> PathBuf {
        dir.join(format!("shard-{shard}.sock"))
    }

    /// The temp directory holding this transport's socket files (removed
    /// when the transport drops).
    pub fn socket_dir(&self) -> &Path {
        &self.dir
    }

    /// Reconnections performed after broken-pipe sends (diagnostics).
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Fault hook: shut down the `src -> dst` delta connection's stream
    /// so the next send trips the reconnect-with-backoff path. The
    /// endpoint stays bound, so the reconnect succeeds — this severs one
    /// connection, not the peer.
    pub fn sever_delta_connection(&self, src: usize, dst: usize) {
        if let Some(conn) = &self.conns[src * self.k + dst] {
            let conn = conn.lock().unwrap();
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
    }

    /// Fault hook: shut down both ends of the `requester -> owner` pull
    /// lane; subsequent pulls on the lane fail fast and are counted as
    /// pull timeouts instead of hanging the admitting worker.
    pub fn sever_pull_lane(&self, requester: usize, owner: usize) {
        if let Some(lane) = &self.pulls[requester * self.k + owner] {
            let lane = lane.lock().unwrap();
            let _ = lane.near.shutdown(std::net::Shutdown::Both);
            let _ = lane.far.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl<V> Drop for SocketTransport<'_, V> {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        for conn in self.conns.iter().flatten() {
            let conn = conn.lock().unwrap_or_else(|p| p.into_inner());
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

impl<V: VertexCodec + Clone + Send + Sync> GhostTransport<V> for SocketTransport<'_, V> {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn send(&self, src_shard: usize, vertex: VertexId, version: u64, data: &V) -> SendReceipt {
        let sites = self.graph.replicas_of(vertex);
        if sites.is_empty() {
            return SendReceipt::default();
        }
        crate::telemetry::instant(
            crate::telemetry::EventKind::WireSend,
            vertex as u64,
            version,
        );
        let delta = GhostDelta::from_vertex(vertex, version, data);
        let mut frame = Vec::with_capacity(delta.wire_len());
        delta.encode_into(&mut frame);
        let mut bytes = 0u64;
        for &(s, gi) in sites {
            let dst = s as usize;
            // Advance the pending slot before the bytes leave so a
            // staleness probe never sees an in-flight version it cannot
            // account for.
            self.graph.shard(dst).ghost(gi as usize).note_pending(version);
            let idx = src_shard * self.k + dst;
            let Some(conn) = &self.conns[idx] else { continue };
            // Bounded send window: block the flush (backpressure) until
            // the reader lands enough in-flight bytes. An empty window
            // always admits the frame, so frames larger than the whole
            // window still make progress. The window is a *soft* bound:
            // the check-then-add is racy across workers of one shard
            // (overshoot of one frame per concurrent sender), and the
            // stall is time-bounded so a reconnect-skewed count can delay
            // a sender but never livelock it.
            let window = &self.window[idx];
            let mut stalled = false;
            // The stall-span clock starts only once the sender actually
            // stalls — the unstalled fast path reads no clock.
            let mut stall_span = crate::telemetry::SPAN_OFF;
            let mut spins = 0u32;
            loop {
                let inflight = window.load(Ordering::Acquire);
                if inflight == 0 || inflight + frame.len() <= self.send_cap {
                    break;
                }
                if !stalled {
                    stalled = true;
                    self.backpressure.fetch_add(1, Ordering::Relaxed);
                    stall_span = crate::telemetry::span_start();
                }
                spins += 1;
                if spins > STALL_ITERS_MAX {
                    break;
                }
                if spins < 64 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
            if stalled {
                crate::telemetry::span_end(
                    crate::telemetry::EventKind::Backpressure,
                    stall_span,
                    vertex as u64,
                    dst as u64,
                );
            }
            window.fetch_add(frame.len(), Ordering::AcqRel);
            conn.lock().unwrap().send(
                &frame,
                vertex,
                dst,
                window,
                &self.reconnects,
                &self.backoffs,
            );
            bytes += frame.len() as u64;
        }
        SendReceipt { replicas_now: 0, bytes }
    }

    fn drain(&self, dst_shard: usize) -> DrainReceipt {
        let mut out = DrainReceipt::default();
        if self.k < 2 {
            return out;
        }
        let buf = {
            let mut q = self.inboxes[dst_shard].lock().unwrap();
            std::mem::take(&mut *q)
        };
        if buf.is_empty() {
            return out;
        }
        out.bytes = buf.len() as u64;
        let shard = self.graph.shard(dst_shard);
        let mut r = ByteReader::new(&buf);
        while !r.is_empty() {
            let Some(delta) = GhostDelta::decode_from(&mut r) else {
                debug_assert!(false, "torn frame reached the inbox of shard {dst_shard}");
                break;
            };
            let Some(value) = delta.decode_vertex::<V>() else {
                debug_assert!(false, "codec round-trip failed for vertex {}", delta.vertex);
                continue;
            };
            if let Some(entry) = shard.ghost_of(delta.vertex) {
                if entry.store_versioned(&value, delta.version) {
                    out.applied += 1;
                    crate::telemetry::instant(
                        crate::telemetry::EventKind::WireApply,
                        delta.vertex as u64,
                        delta.version,
                    );
                }
            }
        }
        out
    }

    fn pull<'m>(
        &self,
        dst_shard: usize,
        req: PullRequest,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> PullReceipt {
        let owner = self.graph.owner_of(req.vertex);
        let Some(lane) = &self.pulls[dst_shard * self.k + owner] else {
            return PullReceipt::default();
        };
        let mut lane = lane.lock().unwrap();
        let mut bytes = 0u64;
        // Any lane IO failure — timeout against a dead peer, or a severed
        // lane's broken pipe — fails the pull cleanly and is counted; the
        // engine's scope-admission retry loop owns recovery. A crashed
        // peer therefore delays the admitting worker, never hangs it.
        let lane_down = |_e: std::io::Error| {
            self.lane_timeouts.fetch_add(1, Ordering::Relaxed);
            PullReceipt::default()
        };
        // Requester -> owner: the request frame crosses the socket.
        let mut frame = Vec::with_capacity(PullRequest::WIRE_LEN);
        req.encode_into(&mut frame);
        if let Err(e) = lane.near.write_all(&frame) {
            return lane_down(e);
        }
        bytes += frame.len() as u64;
        let mut raw = [0u8; PullRequest::WIRE_LEN];
        if let Err(e) = lane.far.read_exact(&mut raw) {
            return lane_down(e);
        }
        // Owner side: serve the master data as a delta frame. Lock-step
        // chunked exchange — the same thread plays both ends, so at most
        // PULL_CHUNK reply bytes are ever in the kernel buffer.
        let Some(reply) = super::serve_pull(&raw, master) else {
            debug_assert!(false, "corrupt pull request on {dst_shard}->{owner}");
            return PullReceipt { applied: false, served: true, bytes };
        };
        let mut got = vec![0u8; reply.len()];
        let mut off = 0usize;
        while off < reply.len() {
            let end = (off + PULL_CHUNK).min(reply.len());
            if let Err(e) = lane.far.write_all(&reply[off..end]) {
                return lane_down(e);
            }
            if let Err(e) = lane.near.read_exact(&mut got[off..end]) {
                return lane_down(e);
            }
            off = end;
        }
        bytes += reply.len() as u64;
        // Requester side: decode the reply and apply it (newest wins).
        let Some(applied) = super::apply_pull_reply(self.graph, dst_shard, &got) else {
            debug_assert!(false, "corrupt pull reply on {owner}->{dst_shard}");
            return PullReceipt { applied: false, served: true, bytes };
        };
        PullReceipt { applied, served: true, bytes }
    }

    fn queued_bytes(&self, dst_shard: usize) -> u64 {
        let mut total = self.inboxes[dst_shard].lock().unwrap().len() as u64;
        for src in 0..self.k {
            total += self.window[src * self.k + dst_shard].load(Ordering::Acquire) as u64;
        }
        total
    }

    fn finalize(&self) {
        // Wait (bounded, ~10s) until every written byte has landed in an
        // inbox: senders only write whole frames, so a zero window means
        // the inboxes hold the complete, frame-aligned stream. On timeout
        // — overloaded machine, or a reconnect-skewed window count — warn
        // loudly rather than fail silently: the caller's final drain may
        // miss in-flight deltas.
        for _ in 0..100_000 {
            let inflight: usize =
                self.window.iter().map(|w| w.load(Ordering::Acquire)).sum();
            if inflight == 0 {
                return;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        let inflight: usize = self.window.iter().map(|w| w.load(Ordering::Acquire)).sum();
        eprintln!(
            "graphlab socket transport: finalize timed out with {inflight} bytes \
             in flight; the final drain may miss ghost deltas"
        );
        debug_assert!(false, "socket transport finalize timed out with bytes in flight");
    }

    fn backpressure_stalls(&self) -> u64 {
        self.backpressure.load(Ordering::Relaxed)
    }

    fn pull_timeouts(&self) -> u64 {
        self.lane_timeouts.load(Ordering::Relaxed)
    }

    fn reconnect_backoffs(&self) -> u64 {
        self.backoffs.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DataGraph, GraphBuilder};

    fn chain(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(i as u64);
        }
        for i in 0..n - 1 {
            b.add_undirected(i as u32, i as u32 + 1, (), ());
        }
        b.build()
    }

    #[test]
    fn deltas_cross_the_socket_and_apply_on_drain() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        assert!(t.socket_dir().exists(), "socket files live in the temp dir");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        let r = GhostTransport::send(&t, owner, v, 4, &777u64);
        assert!(r.bytes > 0, "socket backend really ships bytes");
        assert_eq!(r.replicas_now, 0, "socket applies at drain, not send");
        assert_eq!(entry.pending_version(), 4, "in-flight version visible");
        GhostTransport::finalize(&t);
        let d = GhostTransport::drain(&t, dst as usize);
        assert_eq!(d.applied, 1);
        assert_eq!(d.bytes, r.bytes, "every shipped byte consumed");
        assert_eq!(entry.read(), 777, "payload round-tripped the socket");
        assert_eq!(entry.version(), 4);
        assert_eq!(GhostTransport::queued_bytes(&t, dst as usize), 0);

        let dir = t.socket_dir().to_path_buf();
        drop(t);
        assert!(!dir.exists(), "socket files cleaned up on drop");
    }

    #[test]
    fn severed_delta_connection_reconnects_with_backoff() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);
        t.sever_delta_connection(owner, dst as usize);
        let r = GhostTransport::send(&t, owner, v, 2, &555u64);
        assert!(r.bytes > 0);
        assert!(t.reconnects() >= 1, "a broken pipe must reconnect");
        assert!(
            GhostTransport::reconnect_backoffs(&t) >= 1,
            "each reconnect attempt waits one counted backoff"
        );
        // The resent frame lands on the fresh connection; poll the drain
        // (bounded) rather than finalize — the torn write skews the
        // window accounting, which finalize only tolerates noisily.
        let mut applied = 0;
        for _ in 0..10_000 {
            applied += GhostTransport::drain(&t, dst as usize).applied;
            if applied > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(applied, 1, "the severed frame was resent and applied");
        assert_eq!(entry.read(), 555);
        assert_eq!(entry.version(), 2);
    }

    #[test]
    fn severed_pull_lane_fails_fast_and_counts_a_timeout() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = SocketTransport::new(&sg).expect("socket setup");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, _gi) = sg.replicas_of(v)[0];
        t.sever_pull_lane(dst as usize, owner);
        let master = 999u64;
        let r = GhostTransport::pull(
            &t,
            dst as usize,
            PullRequest { vertex: v, min_version: 1 },
            &|u| {
                assert_eq!(u, v);
                (&master, 1)
            },
        );
        assert!(!r.applied && !r.served, "a severed lane fails the pull cleanly");
        assert_eq!(GhostTransport::pull_timeouts(&t), 1, "the failure is counted");
    }

    #[test]
    fn partial_frames_never_reach_the_inbox() {
        let inbox = Mutex::new(Vec::new());
        let d = GhostDelta::from_vertex(3, 9, &1234u64);
        let mut frame = Vec::new();
        d.encode_into(&mut frame);
        // Deliver the frame in three fragments: nothing forwards until the
        // final fragment completes it.
        let mut staging = Vec::new();
        staging.extend_from_slice(&frame[..10]);
        forward_frames(&mut staging, &inbox);
        assert!(inbox.lock().unwrap().is_empty());
        staging.extend_from_slice(&frame[10..frame.len() - 1]);
        forward_frames(&mut staging, &inbox);
        assert!(inbox.lock().unwrap().is_empty());
        staging.extend_from_slice(&frame[frame.len() - 1..]);
        forward_frames(&mut staging, &inbox);
        assert_eq!(*inbox.lock().unwrap(), frame);
        assert!(staging.is_empty());
    }
}
