//! The **ghost-sync transport layer**: how an owned vertex's writes reach
//! its ghost replicas on other shards.
//!
//! PR 3's sharded engine flushed replicas by writing directly into the
//! peer shard's ghost table — correct in one address space, but hardwired
//! to it. Distributed GraphLab's locking engine (arXiv:1204.6078) instead
//! pipelines *versioned vertex deltas* over an explicit communication
//! layer, and Petuum's SSP model (arXiv:1312.7651) shows that **bounding
//! replica staleness**, rather than flushing synchronously per boundary
//! update, is what buys asynchronous throughput. This module extracts that
//! seam:
//!
//! * [`VertexCodec`] — byte encoding of a vertex data block (the payload a
//!   real wire would carry);
//! * [`GhostDelta`] — one versioned update record: vertex id, master
//!   version stamp, encoded payload;
//! * [`GhostTransport`] — the backend trait: `send` a delta toward every
//!   remote replica, `drain` the deltas addressed to a shard. Two
//!   backends ship in-crate:
//!   [`DirectTransport`] (the PR 3 in-memory write, now routed through the
//!   trait — applies at `send`, ships zero bytes) and [`ChannelTransport`]
//!   (per-shard-pair byte queues that actually serialize and deserialize
//!   every delta, simulating a multi-process boundary and validating the
//!   codec round-trip on every hop);
//! * [`DeltaBatcher`] — the per-worker coalescing window: repeated writes
//!   to the same vertex inside a sync window collapse to one delta, and
//!   the window flushes on a record-count threshold, on cross-shard task
//!   handoff, on worker idle, and at worker exit.
//!
//! Freshness is governed by the engine's **bounded-staleness** knob
//! (`Program::ghost_staleness(s)`): a reader about to enter a scope that
//! reads a ghost more than `s` master versions behind forces a
//! pull-on-demand from the owner's data first (see
//! `Scope::refresh_stale_ghosts`); `s = 0` reproduces the synchronous
//! read semantics of the per-update flush. The pull flows through the
//! trait's **request/reply path** ([`GhostTransport::pull`]): a
//! [`PullRequest`] frame crosses to the owner, the owner answers with an
//! encoded-vertex reply (a [`GhostDelta`] frame), and the requester
//! applies it — so on a serializing backend a stale reader never touches
//! peer master data directly. A new backend slots in with one
//! [`GhostTransport`] impl — everything above the trait (batching,
//! staleness, counters) is backend-agnostic; [`SocketTransport`] is
//! exactly that: the same frames moved as real Unix-domain-socket bytes
//! (with vectored `writev` flushes batching every staged frame for a
//! destination into one syscall), and [`ShmTransport`] is the same-host
//! fast lane: per-shard-pair lock-free SPSC byte rings over
//! process-shareable memory (see [`ShmTransport`] for the ring layout).
//! [`FaultInjector`] exploits the same seam in the other direction: it
//! wraps any backend in a deterministic lossy wire (drops, duplicates,
//! delays/reorders, severed pulls) to prove the invariants above actually
//! carry the engine through message loss.
//!
//! # Wire format
//!
//! Two frame kinds, both little-endian, both framed by the transport (the
//! [`VertexCodec`] payload itself carries no framing):
//!
//! * **delta frame** — `u32 vertex, u64 version, u32 payload_len,
//!   payload` ([`GhostDelta::encode_into`]); `version` is the owner's
//!   master stamp and replicas apply **newest-wins**
//!   (`GhostEntry::store_versioned`), so duplicated or reordered
//!   deliveries are harmless;
//! * **pull frame** — `u32 vertex, u64 min_version`
//!   ([`PullRequest::encode_into`], fixed [`PullRequest::WIRE_LEN`]
//!   bytes); the reply is an ordinary delta frame carrying the owner's
//!   current data, whose version is `>= min_version` whenever the
//!   requester froze the master under a read lock.
//!
//! The channel backend additionally supports a **compressed** delta frame
//! (varint header + word-run diff against a per-lane shadow copy, raw
//! fallback when the diff would not be smaller) for converging algorithms
//! that re-ship nearly identical payloads — see [`encode_delta`] /
//! [`decode_header`] / [`decode_payload`] and
//! [`ChannelTransport::compressed`]. The socket backend supports the same
//! compressed frames over real kernel bytes
//! ([`SocketTransport::compressed`], exposed as `"socket-z"`), wrapped in
//! a `u32 src, u32 len` envelope with an in-band shadow-reset marker so a
//! reconnect can never desync the diff shadows. Pull frames stay raw on
//! every backend.

#![warn(missing_docs)]

mod channel;
mod codec;
mod compress;
mod direct;
mod fault;
mod shm;
mod socket;

pub use channel::ChannelTransport;
pub use codec::{
    put_f32, put_f32s, put_f64, put_u32, put_u32s, put_u64, put_u8, ByteReader, VertexCodec,
};
pub use compress::{
    decode_header, decode_payload, encode_delta, put_varint, read_varint, CompressedHeader,
};
pub use direct::DirectTransport;
pub use fault::{FaultInjector, FaultPlan};
pub use shm::{shm_ring, ShmConsumer, ShmProducer, ShmTransport, DEFAULT_RING_CAPACITY};
pub use socket::{SocketTransport, DEFAULT_SEND_BUFFER};

use crate::graph::VertexId;

/// One versioned ghost update: the unit a transport ships. `version` is
/// the owner's master version stamp at write time (monotone per vertex);
/// replicas apply a delta only if it is newer than what they hold, so
/// reordered or duplicated deliveries are harmless.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GhostDelta {
    /// Global id of the updated vertex.
    pub vertex: VertexId,
    /// The owner's master version stamp at write time.
    pub version: u64,
    /// [`VertexCodec`]-encoded vertex payload.
    pub payload: Vec<u8>,
}

impl GhostDelta {
    /// Encode `data` into a delta record.
    pub fn from_vertex<V: VertexCodec>(vertex: VertexId, version: u64, data: &V) -> GhostDelta {
        let mut payload = Vec::new();
        data.encode(&mut payload);
        GhostDelta { vertex, version, payload }
    }

    /// Decode the payload back into a vertex data block.
    pub fn decode_vertex<V: VertexCodec>(&self) -> Option<V> {
        V::decode(&self.payload)
    }

    /// Bytes this delta occupies on the wire (frame header + payload).
    pub fn wire_len(&self) -> usize {
        4 + 8 + 4 + self.payload.len()
    }

    /// Append the wire frame: `u32 vertex, u64 version, u32 len, payload`.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        put_u32(buf, self.vertex);
        put_u64(buf, self.version);
        put_u32(buf, self.payload.len() as u32);
        buf.extend_from_slice(&self.payload);
    }

    /// Parse one wire frame from the reader. `None` on truncation.
    pub fn decode_from(r: &mut ByteReader<'_>) -> Option<GhostDelta> {
        let vertex = r.u32()?;
        let version = r.u64()?;
        let len = r.u32()? as usize;
        let payload = r.take(len)?.to_vec();
        Some(GhostDelta { vertex, version, payload })
    }
}

/// What a [`GhostTransport::send`] accomplished immediately.
#[derive(Debug, Clone, Copy, Default)]
pub struct SendReceipt {
    /// Replica writes applied synchronously at send time (direct-memory
    /// backends; queueing backends apply at [`GhostTransport::drain`]).
    pub replicas_now: u64,
    /// Bytes enqueued on the wire (zero for direct-memory backends).
    pub bytes: u64,
}

/// What a [`GhostTransport::drain`] applied.
#[derive(Debug, Clone, Copy, Default)]
pub struct DrainReceipt {
    /// Replica writes applied from queued deltas (zero if every queued
    /// delta was superseded by a newer version already present).
    pub applied: u64,
    /// Bytes consumed off the wire.
    pub bytes: u64,
}

/// A staleness **pull request**: the requester half of the transport's
/// request/reply path. A shard holding a ghost replica that lags past the
/// engine's staleness bound frames one of these toward the owner shard;
/// the reply is a [`GhostDelta`] carrying the owner's current data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PullRequest {
    /// Global id of the vertex whose replica needs refreshing.
    pub vertex: VertexId,
    /// Minimum master version the requester needs. When the requester
    /// holds a read lock on the master (the scope-admission path), this is
    /// the frozen master version and the serve is guaranteed to meet it.
    pub min_version: u64,
}

impl PullRequest {
    /// Fixed wire size of a pull-request frame: `u32 vertex, u64
    /// min_version`.
    pub const WIRE_LEN: usize = 12;

    /// Append the wire frame: `u32 vertex, u64 min_version`.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        put_u32(buf, self.vertex);
        put_u64(buf, self.min_version);
    }

    /// Parse one wire frame from the reader. `None` on truncation.
    pub fn decode_from(r: &mut ByteReader<'_>) -> Option<PullRequest> {
        Some(PullRequest { vertex: r.u32()?, min_version: r.u64()? })
    }
}

/// Outcome of a [`GhostTransport::pull`].
#[derive(Debug, Clone, Copy, Default)]
pub struct PullReceipt {
    /// Was the destination replica actually updated (the reply carried a
    /// version newer than what it held)?
    pub applied: bool,
    /// Did the request and reply cross the transport's byte path? True
    /// for serializing backends; false for [`DirectTransport`]'s in-place
    /// master read.
    pub served: bool,
    /// Request plus reply bytes moved (zero for the direct backend).
    pub bytes: u64,
}

/// A ghost-sync backend. The engine routes **all** replica traffic through
/// this trait; implementations decide whether a delta is applied in place
/// ([`DirectTransport`]), serialized over per-shard-pair queues
/// ([`ChannelTransport`]), or moved as real kernel-socket bytes
/// ([`SocketTransport`]).
pub trait GhostTransport<V>: Send + Sync {
    /// Stable backend name (diagnostics).
    fn name(&self) -> &'static str;

    /// Ship one versioned delta from `src_shard` toward every remote
    /// replica of `vertex`. Must also advance each replica's
    /// pending-delta slot so staleness diagnostics can see in-flight
    /// versions.
    fn send(&self, src_shard: usize, vertex: VertexId, version: u64, data: &V) -> SendReceipt;

    /// Apply every queued delta addressed to `dst_shard`'s ghost table.
    /// No-op for backends that apply at send time.
    fn drain(&self, dst_shard: usize) -> DrainReceipt;

    /// Request/reply pull: refresh `dst_shard`'s ghost replica of
    /// `req.vertex` from the owner's master data. `master` is the
    /// owner-side service function — it returns a borrow of the owner's
    /// current data plus the master version, and the caller guarantees
    /// the borrow is safe for the duration of the call (the engine holds
    /// a read lock on the master). In-process backends invoke it on the
    /// requester's thread *after* the request frame crosses the byte
    /// boundary and frame the reply back through the same path, so the
    /// data a stale reader sees always round-tripped the wire; a true
    /// remote backend would invoke its own owner-side copy instead.
    fn pull<'m>(
        &self,
        dst_shard: usize,
        req: PullRequest,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> PullReceipt;

    /// Issue a batch of staleness pulls. The default loops
    /// [`GhostTransport::pull`] one request at a time; backends with real
    /// request/reply lanes override this to **pipeline**: every request
    /// frame crosses toward its owner before the first reply is read, so
    /// a scope with many stale ghosts pays one lane acquisition instead
    /// of N lock-step round-trips. Receipts are returned in request
    /// order; a request whose vertex is owned by `dst_shard` itself gets
    /// a default (unserved) receipt.
    fn pull_many<'m>(
        &self,
        dst_shard: usize,
        reqs: &[PullRequest],
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> Vec<PullReceipt> {
        reqs.iter().map(|req| self.pull(dst_shard, *req, master)).collect()
    }

    /// `(min, max)` bounds for the sharded engine's adaptive drain tick:
    /// how many interior tasks a worker may run between `queued_bytes`
    /// probes. The defaults are the socket-era bounds (drains cost a
    /// syscall-ish inbox sweep, so backing off far is worth it); cheap
    /// backends like the shm rings override with much tighter bounds so
    /// the adaptive tick cannot throttle them into stale-replica churn.
    fn drain_tick_bounds(&self) -> (u64, u64) {
        (8, 512)
    }

    /// Does `send` apply replicas synchronously in place? When true and
    /// the engine runs in synchronous mode (sync window 1, staleness
    /// bound 0), replicas are provably never stale at scope admission and
    /// the engine skips the per-ghost staleness scan entirely. The
    /// conservative default keeps the scan.
    fn applies_at_send(&self) -> bool {
        false
    }

    /// Bytes currently queued toward `dst_shard` (sent but not yet applied
    /// to its ghost table). The sharded engine adapts its periodic drain
    /// tick on this depth; apply-at-send backends report 0.
    fn queued_bytes(&self, dst_shard: usize) -> u64 {
        let _ = dst_shard;
        0
    }

    /// Barrier called once after every worker has exited and before the
    /// engine's final drain pass: backends with asynchronous delivery
    /// (reader threads, kernel buffers) block here until every sent byte
    /// is drainable, so the final drain observes the complete stream.
    fn finalize(&self) {}

    /// Sends that stalled on a full bounded send buffer (backpressure).
    /// Zero for backends without a bounded send window.
    fn backpressure_stalls(&self) -> u64 {
        0
    }

    /// Faults this backend injected or absorbed (deltas dropped,
    /// duplicated, delayed; pulls severed). Zero for every real backend;
    /// the [`FaultInjector`] wrapper counts its scheduled faults here.
    fn faults_injected(&self) -> u64 {
        0
    }

    /// Pull exchanges that timed out against a dead or severed peer lane
    /// (the socket backend's bounded-read path). Zero for backends whose
    /// pulls cannot block.
    fn pull_timeouts(&self) -> u64 {
        0
    }

    /// Exponential-backoff waits spent reconnecting a severed delta
    /// connection (the socket backend; one count per reconnect attempt).
    /// Zero for backends without reconnectable connections.
    fn reconnect_backoffs(&self) -> u64 {
        0
    }

    /// Best master version this backend **knows about** for `vertex`,
    /// given the locally observable master version `local`. In one
    /// address space `local` (the shared `master_versions` table) is
    /// authoritative and the default returns it unchanged. A
    /// cross-process backend overrides this with the maximum of `local`
    /// and the versions its peers have *announced* on the wire — that is
    /// the only way a resident shard can ever observe that a
    /// remote-owned master moved, so the engine's bounded-staleness
    /// admission check sources versions through this hook.
    fn known_master_version(&self, vertex: VertexId, local: u64) -> u64 {
        let _ = vertex;
        local
    }

    /// Start this backend's **owner-side pull service** inside the
    /// engine's thread scope, if it has one. A cross-process backend
    /// spawns a scoped thread that accepts peer pull connections, decodes
    /// [`PullRequest`] frames, reads the requested master row through
    /// `master` (which takes the vertex's read lock around the supplied
    /// callback), and writes the reply delta frame back — so pulls are
    /// answered from the **owner's own address space**, never by the
    /// requester reaching into peer memory. `local_done` flips true when
    /// every engine worker has exited; the service drains in-flight
    /// requests, coordinates shutdown with its peers, and returns.
    ///
    /// Returns whether a service thread was actually started. The
    /// default (every in-process backend) starts nothing: their pulls
    /// are served on the requester's thread against shared memory.
    fn serve_pulls<'scope, 'env>(
        &'scope self,
        scope: &'scope std::thread::Scope<'scope, 'env>,
        master: MasterServe<'scope, V>,
        local_done: &'scope std::sync::atomic::AtomicBool,
    ) -> bool {
        let _ = (scope, master, local_done);
        false
    }
}

/// The owner-side master-row reader handed to
/// [`GhostTransport::serve_pulls`]: invoked with a locally-owned vertex
/// id, it acquires that vertex's read lock, then calls the supplied
/// callback with a borrow of the master data and the current master
/// version (releasing the lock when the callback returns). The
/// continuation shape keeps the vertex-codec bound off the engine core:
/// the service thread encodes the row inside the callback and does its
/// socket writes after the lock is released.
pub type MasterServe<'a, V> = &'a (dyn Fn(VertexId, &mut dyn FnMut(&V, u64)) + Sync);

/// Owner-side half of a pull exchange, shared by the serializing
/// backends: decode the request frame off `raw`, serve it from the
/// `master` service, and return the encoded reply delta frame. `None` on
/// a corrupt request frame.
pub(crate) fn serve_pull<'m, V: VertexCodec>(
    raw: &[u8],
    master: &dyn Fn(VertexId) -> (&'m V, u64),
) -> Option<Vec<u8>> {
    let mut r = ByteReader::new(raw);
    let request = PullRequest::decode_from(&mut r)?;
    let (data, version) = master(request.vertex);
    debug_assert!(
        version >= request.min_version,
        "pull for vertex {} served version {version} below requested {}",
        request.vertex,
        request.min_version
    );
    let delta = GhostDelta::from_vertex(request.vertex, version, data);
    let mut reply = Vec::with_capacity(delta.wire_len());
    delta.encode_into(&mut reply);
    Some(reply)
}

/// Requester-side half of a pull exchange, shared by the serializing
/// backends: decode the reply delta frame and apply it to `dst_shard`'s
/// ghost table (newest version wins). Returns whether the replica was
/// updated; `None` on a corrupt reply frame.
pub(crate) fn apply_pull_reply<V: VertexCodec + Clone>(
    graph: &crate::graph::ShardedGraph<V>,
    dst_shard: usize,
    raw: &[u8],
) -> Option<bool> {
    let mut r = ByteReader::new(raw);
    let delta = GhostDelta::decode_from(&mut r)?;
    let value = delta.decode_vertex::<V>()?;
    Some(
        graph
            .shard(dst_shard)
            .ghost_of(delta.vertex)
            .map(|e| e.store_versioned(&value, delta.version))
            .unwrap_or(false),
    )
}

/// Outcome of a [`DeltaBatcher::flush`].
#[derive(Debug, Clone, Copy, Default)]
pub struct FlushReceipt {
    /// Deltas handed to the transport.
    pub deltas: u64,
    /// Replica writes the transport applied synchronously.
    pub replicas: u64,
    /// Bytes the transport enqueued.
    pub bytes: u64,
}

/// Per-worker delta batcher: coalesces repeated writes to the same vertex
/// within a sync window. A **record** is one boundary-vertex write; the
/// window closes (flushes) once `window` records accumulate — so `window
/// = 1` is the synchronous per-update flush of PR 3, and larger windows
/// trade replica freshness (bounded by the engine's staleness pulls) for
/// fewer, fatter sends. The engine also flushes on cross-shard handoff,
/// on going idle, and at worker exit.
pub struct DeltaBatcher<V> {
    slots: Vec<(VertexId, u64, V)>,
    /// vertex -> position in `slots`: keeps `record` O(1) even when a wide
    /// sync window holds a shard's whole boundary set (record sits on the
    /// engine's boundary-update hot path).
    index: std::collections::HashMap<VertexId, usize>,
    records: usize,
    window: usize,
}

impl<V> DeltaBatcher<V> {
    /// `window` is clamped to at least 1.
    pub fn new(window: usize) -> DeltaBatcher<V> {
        DeltaBatcher {
            slots: Vec::new(),
            index: std::collections::HashMap::new(),
            records: 0,
            window: window.max(1),
        }
    }

    /// Nothing batched this window?
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Distinct vertices currently batched.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Record one owned-vertex write. Called under the vertex's write
    /// lock; the batcher copies `data` into its slot itself —
    /// `clone_from` on a coalescing hit, so a repeatedly-written vertex
    /// reuses one slot's buffers instead of allocating a fresh deep clone
    /// per write. Returns `true` if an existing slot was coalesced (same
    /// vertex already batched this window).
    pub fn record(&mut self, vertex: VertexId, version: u64, data: &V) -> bool
    where
        V: Clone,
    {
        self.records += 1;
        match self.index.entry(vertex) {
            std::collections::hash_map::Entry::Occupied(e) => {
                let slot = &mut self.slots[*e.get()];
                slot.1 = version;
                slot.2.clone_from(data);
                true
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(self.slots.len());
                self.slots.push((vertex, version, data.clone()));
                false
            }
        }
    }

    /// Has the sync window closed?
    pub fn should_flush(&self) -> bool {
        self.records >= self.window
    }

    /// Ship every batched slot through `transport` and reset the window.
    pub fn flush(&mut self, src_shard: usize, transport: &dyn GhostTransport<V>) -> FlushReceipt {
        let mut out = FlushReceipt::default();
        for (vertex, version, data) in self.slots.drain(..) {
            let r = transport.send(src_shard, vertex, version, &data);
            out.deltas += 1;
            out.replicas += r.replicas_now;
            out.bytes += r.bytes;
        }
        self.index.clear();
        self.records = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn delta_wire_round_trip_multiple_frames() {
        let a = GhostDelta::from_vertex(3, 7, &42u64);
        let b = GhostDelta::from_vertex(9, 8, &(1u64, 2u64));
        let mut buf = Vec::new();
        a.encode_into(&mut buf);
        b.encode_into(&mut buf);
        assert_eq!(buf.len(), a.wire_len() + b.wire_len());
        let mut r = ByteReader::new(&buf);
        assert_eq!(GhostDelta::decode_from(&mut r), Some(a.clone()));
        assert_eq!(GhostDelta::decode_from(&mut r), Some(b.clone()));
        assert!(r.is_empty());
        assert_eq!(a.decode_vertex::<u64>(), Some(42));
        assert_eq!(b.decode_vertex::<(u64, u64)>(), Some((1, 2)));
    }

    #[test]
    fn truncated_frame_rejected() {
        let d = GhostDelta::from_vertex(1, 1, &5u64);
        let mut buf = Vec::new();
        d.encode_into(&mut buf);
        buf.pop();
        let mut r = ByteReader::new(&buf);
        assert!(GhostDelta::decode_from(&mut r).is_none());
    }

    #[test]
    fn pull_request_wire_round_trip() {
        let req = PullRequest { vertex: 17, min_version: 99 };
        let mut buf = Vec::new();
        req.encode_into(&mut buf);
        assert_eq!(buf.len(), PullRequest::WIRE_LEN);
        let mut r = ByteReader::new(&buf);
        assert_eq!(PullRequest::decode_from(&mut r), Some(req));
        assert!(r.is_empty());
        // truncation rejected
        let mut r = ByteReader::new(&buf[..PullRequest::WIRE_LEN - 1]);
        assert!(PullRequest::decode_from(&mut r).is_none());
    }

    /// A counting transport: every send records one delta per call.
    struct Counting {
        sends: AtomicU64,
        last_version: AtomicU64,
    }
    impl GhostTransport<u64> for Counting {
        fn name(&self) -> &'static str {
            "counting"
        }
        fn send(&self, _src: usize, _v: u32, version: u64, _data: &u64) -> SendReceipt {
            self.sends.fetch_add(1, Ordering::Relaxed);
            self.last_version.store(version, Ordering::Relaxed);
            SendReceipt { replicas_now: 1, bytes: 8 }
        }
        fn drain(&self, _dst: usize) -> DrainReceipt {
            DrainReceipt::default()
        }
        fn pull<'m>(
            &self,
            _dst: usize,
            _req: PullRequest,
            _master: &dyn Fn(u32) -> (&'m u64, u64),
        ) -> PullReceipt {
            PullReceipt::default()
        }
    }

    #[test]
    fn batcher_coalesces_and_flushes_on_window() {
        let t = Counting { sends: AtomicU64::new(0), last_version: AtomicU64::new(0) };
        let mut b: DeltaBatcher<u64> = DeltaBatcher::new(4);
        assert!(!b.record(5, 1, &10));
        assert!(b.record(5, 2, &11), "same vertex coalesces");
        assert!(!b.record(6, 3, &12));
        assert!(!b.should_flush(), "3 records < window 4");
        assert!(b.record(5, 4, &13));
        assert!(b.should_flush());
        assert_eq!(b.len(), 2, "two distinct vertices");
        let r = b.flush(0, &t);
        assert_eq!(r.deltas, 2);
        assert_eq!(r.replicas, 2);
        assert_eq!(t.sends.load(Ordering::Relaxed), 2);
        assert!(b.is_empty());
        assert!(!b.should_flush(), "window reset");
        // the coalesced slot shipped its *latest* version
        assert!(t.last_version.load(Ordering::Relaxed) >= 3);
    }

    #[test]
    fn window_one_is_synchronous() {
        let t = Counting { sends: AtomicU64::new(0), last_version: AtomicU64::new(0) };
        let mut b: DeltaBatcher<u64> = DeltaBatcher::new(0); // clamps to 1
        b.record(1, 1, &0);
        assert!(b.should_flush(), "window 1 closes on every record");
        b.flush(0, &t);
        assert_eq!(t.sends.load(Ordering::Relaxed), 1);
    }
}
