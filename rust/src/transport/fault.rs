//! **Fault injection** for the ghost-sync transport: a [`FaultInjector`]
//! wraps any [`GhostTransport`] backend and perturbs its traffic on a
//! deterministic seeded schedule — dropping, duplicating, and delaying
//! (reordering) delta frames, and severing pull exchanges mid-flight.
//!
//! GraphLab in the Cloud (arXiv:1107.0922) motivates the exercise: a
//! long-running engine on EC2-class infrastructure must survive lost and
//! delayed messages rather than assume a perfect wire. The transport's
//! invariants make each fault class survivable by construction:
//!
//! * **duplicates / reorders** — replicas apply newest-wins
//!   (`GhostEntry::store_versioned`), so a stale or repeated delta is a
//!   no-op;
//! * **drops** — the master copy is never lost (ghosts are caches); a
//!   reader that trips the bounded-staleness admission check heals the
//!   replica with a pull, retrying with backoff if the pull itself is
//!   faulty (`Scope::refresh_stale_ghosts`);
//! * **severed pulls** — surface as a failed [`PullReceipt`], which the
//!   admission path retries up to `EngineConfig::pull_retry_limit` times
//!   before admitting the stale read; a dead peer delays admission, never
//!   hangs it.
//!
//! All randomness comes from a [`Pcg32`] seeded by the plan — two runs
//! with the same plan over the same traffic sequence make identical
//! drop/duplicate/delay/sever decisions. No wall-clock entropy anywhere.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::{DrainReceipt, GhostTransport, PullReceipt, PullRequest, SendReceipt};
use crate::graph::VertexId;
use crate::util::Pcg32;

/// A deterministic fault schedule: per-mille rates for each fault class,
/// rolled from a [`Pcg32`] stream seeded by `seed`. Rates are evaluated
/// in declaration order against a single roll in `0..1000`, so their sum
/// must stay `<= 1000`; the remainder passes traffic through untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultPlan {
    /// Seed for the injector's deterministic RNG stream.
    pub seed: u64,
    /// Per-mille of delta sends silently dropped (never reach the inner
    /// backend; healed by staleness pulls).
    pub drop_per_mille: u32,
    /// Per-mille of delta sends delivered twice (absorbed by newest-wins
    /// versioning).
    pub dup_per_mille: u32,
    /// Per-mille of delta sends held back and re-injected one to three
    /// drain ticks later — by which time newer versions have usually
    /// overtaken them, so a delay is also a reorder.
    pub delay_per_mille: u32,
    /// Per-mille of pull exchanges severed mid-flight: the pull returns a
    /// failed receipt without touching the inner backend (the admission
    /// path's retry/backoff loop takes it from there).
    pub sever_per_mille: u32,
}

impl FaultPlan {
    fn checked(self) -> FaultPlan {
        assert!(
            self.drop_per_mille + self.dup_per_mille + self.delay_per_mille <= 1000,
            "fault plan delta rates exceed 1000 per mille"
        );
        assert!(self.sever_per_mille <= 1000, "fault plan sever rate exceeds 1000 per mille");
        self
    }
}

/// A delta held back by the delay schedule, due for re-injection once the
/// global drain tick reaches `due_tick`.
struct Held<V> {
    src_shard: usize,
    vertex: VertexId,
    version: u64,
    data: V,
    due_tick: u64,
}

/// A lossy-wire wrapper around any [`GhostTransport`] backend. See the
/// [module docs](self) for the fault classes and why each is survivable.
///
/// The wrapper always reports [`GhostTransport::applies_at_send`] as
/// `false`, even over the direct backend: a lossy wire can never prove
/// replicas fresh at admission, so the engine must keep its per-ghost
/// staleness scan (the healing path) active.
pub struct FaultInjector<'a, V> {
    inner: &'a dyn GhostTransport<V>,
    plan: FaultPlan,
    rng: Mutex<Pcg32>,
    held: Mutex<Vec<Held<V>>>,
    /// Global drain tick: advances on every `drain` call and schedules
    /// held-delta release.
    drains: AtomicU64,
    faults: AtomicU64,
}

impl<'a, V> FaultInjector<'a, V> {
    /// Wrap `inner` under `plan`. Panics if the plan's rates are
    /// inconsistent (delta rates summing past 1000 per mille).
    pub fn new(inner: &'a dyn GhostTransport<V>, plan: FaultPlan) -> FaultInjector<'a, V> {
        let plan = plan.checked();
        FaultInjector {
            inner,
            plan,
            rng: Mutex::new(Pcg32::seed_from_u64(plan.seed)),
            held: Mutex::new(Vec::new()),
            drains: AtomicU64::new(0),
            faults: AtomicU64::new(0),
        }
    }

    /// Faults injected so far (drops + duplicates + delays + severs).
    pub fn faults(&self) -> u64 {
        self.faults.load(Ordering::Relaxed)
    }

    /// Deltas currently held back by the delay schedule.
    pub fn held_len(&self) -> usize {
        self.held.lock().unwrap().len()
    }

    /// Roll one fault decision in `0..1000` (plus a hold-ticks roll for
    /// delays, drawn from the same stream to keep the schedule a single
    /// deterministic sequence).
    fn roll(&self) -> (u32, u64) {
        let mut rng = self.rng.lock().unwrap();
        (rng.gen_range(1000), 1 + rng.gen_range(3) as u64)
    }

    /// Re-inject every held delta whose tick has come due.
    fn release_due(&self, now: u64)
    where
        V: Clone + Send + Sync,
    {
        let due: Vec<Held<V>> = {
            let mut held = self.held.lock().unwrap();
            let mut due = Vec::new();
            let mut i = 0;
            while i < held.len() {
                if held[i].due_tick <= now {
                    due.push(held.swap_remove(i));
                } else {
                    i += 1;
                }
            }
            due
        };
        for d in due {
            self.inner.send(d.src_shard, d.vertex, d.version, &d.data);
        }
    }
}

impl<V: Clone + Send + Sync> GhostTransport<V> for FaultInjector<'_, V> {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn send(&self, src_shard: usize, vertex: VertexId, version: u64, data: &V) -> SendReceipt {
        let (roll, hold_ticks) = self.roll();
        let p = self.plan;
        if roll < p.drop_per_mille {
            self.faults.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::instant(crate::telemetry::EventKind::Fault, 0, vertex as u64);
            return SendReceipt::default();
        }
        if roll < p.drop_per_mille + p.dup_per_mille {
            self.faults.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::instant(crate::telemetry::EventKind::Fault, 1, vertex as u64);
            let first = self.inner.send(src_shard, vertex, version, data);
            let second = self.inner.send(src_shard, vertex, version, data);
            return SendReceipt {
                replicas_now: first.replicas_now + second.replicas_now,
                bytes: first.bytes + second.bytes,
            };
        }
        if roll < p.drop_per_mille + p.dup_per_mille + p.delay_per_mille {
            self.faults.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::instant(crate::telemetry::EventKind::Fault, 2, vertex as u64);
            let due_tick = self.drains.load(Ordering::Relaxed) + hold_ticks;
            self.held.lock().unwrap().push(Held {
                src_shard,
                vertex,
                version,
                data: data.clone(),
                due_tick,
            });
            return SendReceipt::default();
        }
        self.inner.send(src_shard, vertex, version, data)
    }

    fn drain(&self, dst_shard: usize) -> DrainReceipt {
        let now = self.drains.fetch_add(1, Ordering::Relaxed) + 1;
        self.release_due(now);
        self.inner.drain(dst_shard)
    }

    fn pull<'m>(
        &self,
        dst_shard: usize,
        req: PullRequest,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> PullReceipt {
        let (roll, _) = self.roll();
        if roll < self.plan.sever_per_mille {
            self.faults.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::instant(crate::telemetry::EventKind::Fault, 3, req.vertex as u64);
            return PullReceipt::default();
        }
        self.inner.pull(dst_shard, req, master)
    }

    fn applies_at_send(&self) -> bool {
        // A lossy wire can never prove replicas fresh: keep the engine's
        // staleness scan (the drop-healing path) active even over the
        // direct backend.
        false
    }

    fn queued_bytes(&self, dst_shard: usize) -> u64 {
        self.inner.queued_bytes(dst_shard)
    }

    fn finalize(&self) {
        // Every held delta is released before the inner barrier so the
        // engine's final drain pass observes the complete stream.
        self.release_due(u64::MAX);
        self.inner.finalize();
    }

    fn backpressure_stalls(&self) -> u64 {
        self.inner.backpressure_stalls()
    }

    fn faults_injected(&self) -> u64 {
        self.faults.load(Ordering::Relaxed) + self.inner.faults_injected()
    }

    fn pull_timeouts(&self) -> u64 {
        self.inner.pull_timeouts()
    }

    fn reconnect_backoffs(&self) -> u64 {
        self.inner.reconnect_backoffs()
    }

    fn known_master_version(&self, vertex: VertexId, local: u64) -> u64 {
        // Version announcements are control-plane metadata, not ghost
        // traffic: the lossy schedule never perturbs them.
        self.inner.known_master_version(vertex, local)
    }

    fn serve_pulls<'scope, 'env>(
        &'scope self,
        scope: &'scope std::thread::Scope<'scope, 'env>,
        master: super::MasterServe<'scope, V>,
        local_done: &'scope std::sync::atomic::AtomicBool,
    ) -> bool {
        self.inner.serve_pulls(scope, master, local_done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Records every delivery the inner backend sees.
    #[derive(Default)]
    struct Recording {
        delivered: Mutex<Vec<(VertexId, u64)>>,
        drains: AtomicU64,
    }

    impl GhostTransport<u64> for Recording {
        fn name(&self) -> &'static str {
            "recording"
        }
        fn send(&self, _src: usize, vertex: u32, version: u64, _data: &u64) -> SendReceipt {
            self.delivered.lock().unwrap().push((vertex, version));
            SendReceipt { replicas_now: 1, bytes: 16 }
        }
        fn drain(&self, _dst: usize) -> DrainReceipt {
            self.drains.fetch_add(1, Ordering::Relaxed);
            DrainReceipt::default()
        }
        fn pull<'m>(
            &self,
            _dst: usize,
            _req: PullRequest,
            _master: &dyn Fn(u32) -> (&'m u64, u64),
        ) -> PullReceipt {
            PullReceipt { applied: true, served: true, bytes: 28 }
        }
    }

    fn drive(plan: FaultPlan) -> (Vec<(VertexId, u64)>, u64) {
        let inner = Recording::default();
        let injector = FaultInjector::new(&inner, plan);
        for i in 0..400u32 {
            injector.send(0, i % 8, u64::from(i) + 1, &7u64);
            if i % 16 == 0 {
                injector.drain(1);
            }
        }
        injector.finalize();
        let faults = injector.faults_injected();
        (inner.delivered.into_inner().unwrap(), faults)
    }

    #[test]
    fn same_seed_same_fault_schedule() {
        let plan = FaultPlan {
            seed: 42,
            drop_per_mille: 150,
            dup_per_mille: 100,
            delay_per_mille: 100,
            sever_per_mille: 0,
        };
        let (a, fa) = drive(plan);
        let (b, fb) = drive(plan);
        assert_eq!(a, b, "same seed must replay the identical delivery sequence");
        assert_eq!(fa, fb);
        assert!(fa > 0, "rates this high must inject on 400 sends");
        let (c, _) = drive(FaultPlan { seed: 43, ..plan });
        assert_ne!(a, c, "a different seed must perturb the schedule");
    }

    #[test]
    fn drop_only_plan_loses_exactly_the_faulted_sends() {
        let plan = FaultPlan { seed: 9, drop_per_mille: 250, ..FaultPlan::default() };
        let (delivered, faults) = drive(plan);
        assert!(faults > 0);
        assert_eq!(delivered.len() as u64, 400 - faults, "each fault is one dropped send");
    }

    #[test]
    fn delay_only_plan_delivers_everything_by_finalize() {
        let plan = FaultPlan { seed: 5, delay_per_mille: 400, ..FaultPlan::default() };
        let inner = Recording::default();
        let injector = FaultInjector::new(&inner, plan);
        for i in 0..100u32 {
            injector.send(0, i, u64::from(i) + 1, &1u64);
        }
        assert!(injector.held_len() > 0, "a 40% delay rate must hold some deltas");
        injector.finalize();
        assert_eq!(injector.held_len(), 0, "finalize releases every held delta");
        let delivered = inner.delivered.lock().unwrap();
        assert_eq!(delivered.len(), 100, "delays lose nothing");
        let versions: std::collections::BTreeSet<u64> =
            delivered.iter().map(|&(_, ver)| ver).collect();
        assert_eq!(versions.len(), 100, "every version arrives exactly once");
        let in_order = delivered.windows(2).all(|w| w[0].1 <= w[1].1);
        assert!(!in_order, "held deltas re-inject late: delays are reorders");
    }

    #[test]
    fn dup_only_plan_delivers_extra_copies() {
        let plan = FaultPlan { seed: 11, dup_per_mille: 300, ..FaultPlan::default() };
        let (delivered, faults) = drive(plan);
        assert!(faults > 0);
        assert_eq!(delivered.len() as u64, 400 + faults, "each fault is one extra copy");
    }

    #[test]
    fn severed_pulls_fail_without_reaching_the_backend() {
        let inner = Recording::default();
        let plan = FaultPlan { seed: 3, sever_per_mille: 1000, ..FaultPlan::default() };
        let injector = FaultInjector::new(&inner, plan);
        let master_data = 5u64;
        let r = injector.pull(1, PullRequest { vertex: 2, min_version: 1 }, &|_| (&master_data, 1));
        assert!(!r.applied && !r.served && r.bytes == 0, "severed pull is a clean failure");
        assert_eq!(injector.faults_injected(), 1);
        let open = FaultInjector::new(&inner, FaultPlan { sever_per_mille: 0, ..plan });
        let r = open.pull(1, PullRequest { vertex: 2, min_version: 1 }, &|_| (&master_data, 1));
        assert!(r.applied && r.served, "a zero sever rate passes pulls through");
    }

    #[test]
    fn injector_never_claims_apply_at_send() {
        let inner = Recording::default();
        let injector = FaultInjector::new(&inner, FaultPlan::default());
        assert!(!injector.applies_at_send(), "staleness scan must stay active under faults");
    }

    #[test]
    #[should_panic(expected = "exceed 1000")]
    fn inconsistent_plan_rejected() {
        let inner = Recording::default();
        let _ = FaultInjector::new(
            &inner,
            FaultPlan {
                seed: 0,
                drop_per_mille: 600,
                dup_per_mille: 300,
                delay_per_mille: 200,
                sever_per_mille: 0,
            },
        );
    }
}
