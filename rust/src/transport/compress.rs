//! Delta compression for ghost-sync wire frames.
//!
//! The raw [`super::GhostDelta`] frame spends a flat 16 bytes of header
//! (`u32 vertex`, `u64 version`, `u32 len`) plus the full codec payload on
//! every delta, even when a converging algorithm (residual BP late in its
//! run) re-ships a payload that is byte-identical to the last one sent on
//! the same lane, or differs in only one message slot.
//!
//! The compressed frame fixes both costs:
//!
//! ```text
//! frame   := varint(vertex) varint(version) tag:u8 varint(payload_len) body
//! tag 0   => body is `payload_len` literal bytes (raw fallback)
//! tag 1   => body is a word-run diff against the per-lane shadow copy
//! diff    := ( varint(skip_words) varint(copy_words) copy_words*4 bytes )*
//!            until skip+copy words cover payload_len/4, then
//!            payload_len%4 literal tail bytes
//! ```
//!
//! Varints are LEB128 (7 bits per byte, low group first), so small vertex
//! ids, versions, and payload lengths take 1–3 bytes instead of 16. The
//! diff body run-length-skips 4-byte words (one `f32`/`u32` lane each)
//! that are unchanged since the last frame shipped for the same vertex on
//! the same lane. The encoder builds the diff into scratch and falls back
//! to tag 0 whenever the diff would not be strictly smaller, so a
//! compressed frame is never larger than `header + payload`.
//!
//! Both endpoints keep a *shadow* — the payload bytes as of the last frame
//! for each vertex — and the scheme is only sound if sender and receiver
//! shadows agree when a diff frame is decoded. The channel transport
//! guarantees this by encoding and decoding under the per-lane FIFO lock
//! (see [`super::ChannelTransport`]); this module is pure encoding and
//! holds no state of its own.

use crate::graph::VertexId;

/// Largest LEB128 encoding we accept: 10 groups covers a full `u64`.
const MAX_VARINT_BYTES: usize = 10;

/// Append `v` to `out` as a LEB128 varint (1 byte per 7 bits, low first).
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let group = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(group);
            return;
        }
        out.push(group | 0x80);
    }
}

/// Read a LEB128 varint from the front of `buf`, returning the value and
/// the remaining bytes, or `None` if the buffer is truncated or the
/// encoding overflows a `u64`.
pub fn read_varint(buf: &[u8]) -> Option<(u64, &[u8])> {
    let mut v: u64 = 0;
    for (i, &b) in buf.iter().take(MAX_VARINT_BYTES).enumerate() {
        let group = (b & 0x7f) as u64;
        // The 10th group may only carry the top bit of a u64.
        if i == MAX_VARINT_BYTES - 1 && group > 1 {
            return None;
        }
        v |= group << (7 * i);
        if b & 0x80 == 0 {
            return Some((v, &buf[i + 1..]));
        }
    }
    None
}

/// Header of a decoded compressed frame (everything before the body).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompressedHeader {
    /// Vertex the delta targets.
    pub vertex: VertexId,
    /// Master version of the payload.
    pub version: u64,
    /// `true` when the body is a word-run diff against the shadow.
    pub is_diff: bool,
    /// Decoded (post-diff) payload length in bytes.
    pub payload_len: usize,
}

/// Append one compressed frame for `(vertex, version, payload)` to `out`.
///
/// `shadow` is the payload as of the last frame shipped for this vertex on
/// this lane (`None` for a first ship). The diff path is only attempted
/// when the shadow has the same length as the payload — codec payloads for
/// a fixed-arity vertex type are fixed-size, so this is the common case —
/// and is abandoned for the raw path whenever it would not be strictly
/// smaller. Returns the encoded frame length in bytes.
pub fn encode_delta(
    vertex: VertexId,
    version: u64,
    payload: &[u8],
    shadow: Option<&[u8]>,
    out: &mut Vec<u8>,
) -> usize {
    let start = out.len();
    put_varint(out, vertex as u64);
    put_varint(out, version);
    let body_at = out.len();

    if let Some(prev) = shadow {
        if prev.len() == payload.len() && try_encode_diff(payload, prev, out, body_at) {
            return out.len() - start;
        }
    }
    // Raw fallback: tag 0 + literal payload.
    out.truncate(body_at);
    out.push(0);
    put_varint(out, payload.len() as u64);
    out.extend_from_slice(payload);
    out.len() - start
}

/// Try the diff body; returns `false` (leaving junk past `body_at` for the
/// caller to truncate) if the diff is not strictly smaller than raw.
fn try_encode_diff(payload: &[u8], prev: &[u8], out: &mut Vec<u8>, body_at: usize) -> bool {
    // Raw body cost we must beat: tag + varint(len) + payload bytes.
    let mut raw_cost = 1 + payload.len();
    let mut l = payload.len() as u64;
    loop {
        raw_cost += 1;
        l >>= 7;
        if l == 0 {
            break;
        }
    }

    out.push(1);
    put_varint(out, payload.len() as u64);
    let words = payload.len() / 4;
    let mut w = 0;
    while w < words {
        let mut skip = 0;
        while w + skip < words && word_eq(payload, prev, w + skip) {
            skip += 1;
        }
        let mut copy = 0;
        while w + skip + copy < words && !word_eq(payload, prev, w + skip + copy) {
            copy += 1;
        }
        put_varint(out, skip as u64);
        put_varint(out, copy as u64);
        let at = (w + skip) * 4;
        out.extend_from_slice(&payload[at..at + copy * 4]);
        w += skip + copy;
        if out.len() - body_at >= raw_cost {
            return false;
        }
    }
    // Literal tail for payloads that are not a multiple of 4 bytes.
    out.extend_from_slice(&payload[words * 4..]);
    out.len() - body_at < raw_cost
}

#[inline]
fn word_eq(a: &[u8], b: &[u8], w: usize) -> bool {
    a[w * 4..w * 4 + 4] == b[w * 4..w * 4 + 4]
}

/// Decode one frame header from the front of `buf`, returning the header
/// and the remaining bytes (positioned at the body). `None` on truncation
/// or a vertex id that does not fit `u32`.
pub fn decode_header(buf: &[u8]) -> Option<(CompressedHeader, &[u8])> {
    let (vertex, rest) = read_varint(buf)?;
    let vertex = VertexId::try_from(vertex).ok()?;
    let (version, rest) = read_varint(rest)?;
    let (&tag, rest) = rest.split_first()?;
    if tag > 1 {
        return None;
    }
    let (payload_len, rest) = read_varint(rest)?;
    let header = CompressedHeader {
        vertex,
        version,
        is_diff: tag == 1,
        payload_len: usize::try_from(payload_len).ok()?,
    };
    Some((header, rest))
}

/// Decode the body that follows `header`, writing the reconstructed
/// payload into `payload` (cleared first) and returning the remaining
/// bytes past the frame. Diff frames require a `shadow` of exactly
/// `header.payload_len` bytes. `None` on truncation, run overflow, or a
/// missing/mismatched shadow.
pub fn decode_payload<'b>(
    header: &CompressedHeader,
    buf: &'b [u8],
    shadow: Option<&[u8]>,
    payload: &mut Vec<u8>,
) -> Option<&'b [u8]> {
    payload.clear();
    if !header.is_diff {
        if buf.len() < header.payload_len {
            return None;
        }
        payload.extend_from_slice(&buf[..header.payload_len]);
        return Some(&buf[header.payload_len..]);
    }

    let prev = shadow?;
    if prev.len() != header.payload_len {
        return None;
    }
    let words = header.payload_len / 4;
    let mut rest = buf;
    let mut w = 0;
    while w < words {
        let (skip, r) = read_varint(rest)?;
        let (copy, r) = read_varint(r)?;
        let skip = usize::try_from(skip).ok()?;
        let copy = usize::try_from(copy).ok()?;
        if skip > words - w || copy > words - w - skip {
            return None;
        }
        payload.extend_from_slice(&prev[w * 4..(w + skip) * 4]);
        if r.len() < copy * 4 {
            return None;
        }
        payload.extend_from_slice(&r[..copy * 4]);
        rest = &r[copy * 4..];
        w += skip + copy;
    }
    let tail = header.payload_len - words * 4;
    if rest.len() < tail {
        return None;
    }
    payload.extend_from_slice(&rest[..tail]);
    Some(&rest[tail..])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(
        vertex: VertexId,
        version: u64,
        payload: &[u8],
        shadow: Option<&[u8]>,
    ) -> (usize, Vec<u8>) {
        let mut frame = Vec::new();
        let n = encode_delta(vertex, version, payload, shadow, &mut frame);
        assert_eq!(n, frame.len());
        let (header, body) = decode_header(&frame).expect("header");
        assert_eq!(header.vertex, vertex);
        assert_eq!(header.version, version);
        assert_eq!(header.payload_len, payload.len());
        let mut decoded = Vec::new();
        let rest = decode_payload(&header, body, shadow, &mut decoded).expect("payload");
        assert!(rest.is_empty());
        assert_eq!(decoded, payload);
        (n, frame)
    }

    #[test]
    fn varint_round_trips_boundaries() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let (back, rest) = read_varint(&buf).unwrap();
            assert_eq!(back, v);
            assert!(rest.is_empty());
        }
        // Truncated and overlong encodings are rejected.
        assert!(read_varint(&[0x80]).is_none());
        assert!(read_varint(&[0xff; 11]).is_none());
    }

    #[test]
    fn first_ship_uses_raw_tag_with_small_header() {
        let payload = [7u8; 24];
        let (n, frame) = round_trip(3, 1, &payload, None);
        // varint(3) + varint(1) + tag + varint(24) + 24 literal bytes.
        assert_eq!(n, 1 + 1 + 1 + 1 + 24);
        let (header, _) = decode_header(&frame).unwrap();
        assert!(!header.is_diff);
    }

    #[test]
    fn unchanged_payload_compresses_to_one_run() {
        let payload = [9u8; 24];
        let (n, frame) = round_trip(3, 2, &payload, Some(&payload.clone()));
        let (header, _) = decode_header(&frame).unwrap();
        assert!(header.is_diff);
        // header(4) + one (skip=6, copy=0) run = 6 bytes total.
        assert_eq!(n, 6);
    }

    #[test]
    fn all_changed_payload_falls_back_to_raw() {
        let prev = [0u8; 24];
        let next = [1u8; 24];
        let (n, frame) = round_trip(5, 3, &next, Some(&prev));
        let (header, _) = decode_header(&frame).unwrap();
        // diff = (skip 0, copy 6, 24 bytes) = 27 > raw body 26: raw wins.
        assert!(!header.is_diff);
        assert_eq!(n, 1 + 1 + 1 + 1 + 24);
    }

    #[test]
    fn alternating_runs_round_trip() {
        // words: [same, diff, same, same, diff, diff, same, tail...]
        let mut prev = vec![0u8; 30];
        let mut next = vec![0u8; 30];
        for (i, b) in next.iter_mut().enumerate() {
            *b = i as u8;
        }
        for w in [0usize, 2, 3, 6] {
            prev[w * 4..w * 4 + 4].copy_from_slice(&next[w * 4..w * 4 + 4]);
        }
        // Distinct 2-byte tail so the tail path is exercised too.
        prev[28] = next[28];
        let (_, frame) = round_trip(1000, 1 << 40, &next, Some(&prev));
        let (header, _) = decode_header(&frame).unwrap();
        assert!(header.is_diff);
    }

    #[test]
    fn shadow_length_mismatch_forces_raw() {
        let prev = [1u8; 20];
        let next = [1u8; 24];
        let mut frame = Vec::new();
        encode_delta(9, 4, &next, Some(&prev), &mut frame);
        let (header, _) = decode_header(&frame).unwrap();
        assert!(!header.is_diff);
    }

    #[test]
    fn truncated_frames_are_rejected_not_misread() {
        let payload: Vec<u8> = (0..24).collect();
        // Shadow shares the first two words so the Some case emits a real
        // diff frame (skip 2, copy 4) rather than falling back to raw.
        let mut shadow = vec![0u8; 24];
        shadow[..8].copy_from_slice(&payload[..8]);
        for sh in [None, Some(shadow.as_slice())] {
            let mut frame = Vec::new();
            encode_delta(17, 9, &payload, sh, &mut frame);
            for cut in 0..frame.len() {
                let short = &frame[..cut];
                let ok = match decode_header(short) {
                    None => false,
                    Some((h, body)) => {
                        let mut out = Vec::new();
                        decode_payload(&h, body, sh, &mut out).is_some()
                    }
                };
                assert!(!ok, "truncated frame at {cut} decoded");
            }
        }
    }

    #[test]
    fn diff_without_shadow_is_an_error() {
        let prev = [0u8; 16];
        let mut next = prev;
        next[0] = 1;
        let mut frame = Vec::new();
        encode_delta(2, 2, &next, Some(&prev), &mut frame);
        let (header, body) = decode_header(&frame).unwrap();
        assert!(header.is_diff);
        let mut out = Vec::new();
        assert!(decode_payload(&header, body, None, &mut out).is_none());
        let wrong = [0u8; 12];
        assert!(decode_payload(&header, body, Some(&wrong), &mut out).is_none());
    }

    #[test]
    fn streams_of_frames_decode_back_to_back() {
        let a = [1u8; 16];
        let b = [2u8; 16];
        let mut buf = Vec::new();
        encode_delta(1, 1, &a, None, &mut buf);
        encode_delta(1, 2, &b, Some(&a), &mut buf);
        let (h1, rest) = decode_header(&buf).unwrap();
        let mut p1 = Vec::new();
        let rest = decode_payload(&h1, rest, None, &mut p1).unwrap();
        assert_eq!(p1, a);
        let (h2, rest) = decode_header(rest).unwrap();
        let mut p2 = Vec::new();
        let rest = decode_payload(&h2, rest, Some(&p1), &mut p2).unwrap();
        assert_eq!(p2, b);
        assert!(rest.is_empty());
    }
}
