//! **Multicore discrete-event simulator** — the testbed substitute for the
//! paper's 16-core machine (see DESIGN.md §Testbed-substitutions; this host
//! has one physical core, so parallel *wall-clock* speedups cannot be
//! measured directly).
//!
//! Methodology (trace replay): the sequential engine executes the program
//! with the *real* scheduler and captures a [`TaskTrace`] — measured per-task
//! cost and the tasks each update spawned. The simulator replays that trace
//! on `P` virtual processors:
//!
//! * **Causality** — an execution of vertex `v` becomes eligible only after
//!   the update that spawned it completes (spawn counts are matched against
//!   the trace's per-vertex execution counts, reproducing task
//!   de-duplication).
//! * **Consistency conflicts** — a task may start only if its scope locks
//!   (per the configured [`ConsistencyModel`]) can be acquired against the
//!   currently running tasks: write-`v` (all models), read-`N(v)` (edge),
//!   write-`N(v)` (full). Blocked processors idle until a completion —
//!   exactly the lock-wait the real engine would experience.
//! * **Scheduler overhead** — each dispatch charges `sched_overhead_ns`;
//!   strict (single-queue / global-heap) schedulers serialize dispatches
//!   through a global dispenser, relaxed ones shard it `P` ways.
//! * **Discipline** — among eligible, runnable tasks, processors take the
//!   lowest sequential-trace index first, preserving the real scheduler's
//!   ordering decisions while exposing the parallelism between them.
//!
//! A second entry point replays a [`ExecutionPlan`] DAG (planned or barrier
//! mode) for the chromatic Gibbs experiments (Fig 5).

use crate::consistency::ConsistencyModel;
use crate::engine::trace::TaskTrace;
use crate::scheduler::set_scheduler::ExecutionPlan;
use crate::scheduler::Task;
use std::collections::{BTreeSet, BinaryHeap};

/// Adjacency provider for the simulator's conflict model.
pub trait Neighbors: Sync {
    fn neighbors(&self, v: u32) -> &[u32];
}

impl Neighbors for Vec<Vec<u32>> {
    fn neighbors(&self, v: u32) -> &[u32] {
        self.get(v as usize).map(|n| n.as_slice()).unwrap_or(&[])
    }
}

impl<V: Send + Sync, E: Send + Sync> Neighbors for crate::graph::DataGraph<V, E> {
    fn neighbors(&self, v: u32) -> &[u32] {
        crate::graph::DataGraph::neighbors(self, v)
    }
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub processors: usize,
    pub model: ConsistencyModel,
    /// Cost charged per task dispatch (scheduler pop + lock acquisition), ns.
    pub sched_overhead_ns: f64,
    /// Strict schedulers serialize all dispatches through one dispenser.
    pub sched_serialized: bool,
    /// Floor on per-task cost (measured costs below this are clamped), ns.
    pub min_task_ns: f64,
    /// Shared-queue contention factor for relaxed schedulers: effective
    /// dispatch overhead = `sched_overhead_ns * (1 + factor * (P - 1))`
    /// (cache-line bouncing on queue heads grows with the worker count).
    pub contention_factor: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            processors: 1,
            model: ConsistencyModel::Edge,
            sched_overhead_ns: 120.0,
            sched_serialized: false,
            min_task_ns: 40.0,
            contention_factor: 0.0,
        }
    }
}

impl SimConfig {
    pub fn with_processors(mut self, p: usize) -> Self {
        self.processors = p;
        self
    }
    pub fn with_model(mut self, m: ConsistencyModel) -> Self {
        self.model = m;
        self
    }
    pub fn serialized(mut self, yes: bool) -> Self {
        self.sched_serialized = yes;
        self
    }
}

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub processors: usize,
    pub makespan_ns: f64,
    /// Sum of task costs executed (excludes overhead and idle).
    pub busy_ns: f64,
    /// Total processor-idle time (blocked on conflicts or empty queues).
    pub idle_ns: f64,
    pub tasks: usize,
}

impl SimResult {
    /// Fraction of processor-time doing useful work (Fig 5e's y-axis).
    pub fn efficiency(&self) -> f64 {
        if self.makespan_ns <= 0.0 {
            return 1.0;
        }
        self.busy_ns / (self.makespan_ns * self.processors as f64)
    }

    /// Tasks per second per processor (Fig 5c's y-axis).
    pub fn rate_per_proc(&self) -> f64 {
        self.tasks as f64 / (self.makespan_ns * 1e-9) / self.processors as f64
    }
}

/// Run [`simulate_trace`] over a processor list; returns one result per P.
pub fn sweep_processors(
    trace: &TaskTrace,
    initial: &[Task],
    num_vertices: usize,
    neighbors: &dyn Neighbors,
    base: &SimConfig,
    procs: &[usize],
) -> Vec<SimResult> {
    procs
        .iter()
        .map(|&p| simulate_trace(trace, initial, num_vertices, neighbors, &base.clone().with_processors(p)))
        .collect()
}

/// Speedup pairs `(P, makespan(1)/makespan(P))` from [`sweep_processors`]
/// output (a P=1 run must be present or the first entry is used as base).
pub fn speedups(results: &[SimResult]) -> Vec<(usize, f64)> {
    speedup_curve(
        &results.iter().map(|r| (r.processors, r.makespan_ns)).collect::<Vec<_>>(),
    )
}

/// Speedup series helper: `makespan(1) / makespan(P)` over a processor list.
pub fn speedup_curve(makespans: &[(usize, f64)]) -> Vec<(usize, f64)> {
    let base = makespans
        .iter()
        .find(|(p, _)| *p == 1)
        .map(|(_, m)| *m)
        .unwrap_or_else(|| makespans.first().map(|(_, m)| *m).unwrap_or(1.0));
    makespans.iter().map(|&(p, m)| (p, base / m.max(1e-9))).collect()
}

/// Virtual per-vertex lock table mirroring [`crate::consistency::LockTable`].
struct LockSim<'a> {
    model: ConsistencyModel,
    neighbors: &'a dyn Neighbors,
    write_locked: Vec<bool>,
    read_count: Vec<u32>,
}

impl<'a> LockSim<'a> {
    fn new(n: usize, model: ConsistencyModel, neighbors: &'a dyn Neighbors) -> Self {
        LockSim { model, neighbors, write_locked: vec![false; n], read_count: vec![0; n] }
    }

    fn can_run(&self, v: u32) -> bool {
        if self.write_locked[v as usize] || self.read_count[v as usize] > 0 {
            return false;
        }
        match self.model {
            ConsistencyModel::Vertex => true,
            ConsistencyModel::Edge => {
                self.neighbors.neighbors(v).iter().all(|&u| !self.write_locked[u as usize])
            }
            ConsistencyModel::Full => self.neighbors.neighbors(v)
                .iter()
                .all(|&u| !self.write_locked[u as usize] && self.read_count[u as usize] == 0),
        }
    }

    fn acquire(&mut self, v: u32) {
        self.write_locked[v as usize] = true;
        match self.model {
            ConsistencyModel::Vertex => {}
            ConsistencyModel::Edge => {
                for &u in self.neighbors.neighbors(v) {
                    self.read_count[u as usize] += 1;
                }
            }
            ConsistencyModel::Full => {
                for &u in self.neighbors.neighbors(v) {
                    self.write_locked[u as usize] = true;
                }
            }
        }
    }

    fn release(&mut self, v: u32) {
        self.write_locked[v as usize] = false;
        match self.model {
            ConsistencyModel::Vertex => {}
            ConsistencyModel::Edge => {
                for &u in self.neighbors.neighbors(v) {
                    self.read_count[u as usize] -= 1;
                }
            }
            ConsistencyModel::Full => {
                for &u in self.neighbors.neighbors(v) {
                    self.write_locked[u as usize] = false;
                }
            }
        }
    }
}

/// Completion event ordered for a min-heap on time.
struct Completion {
    time: f64,
    item: u32,
}
impl PartialEq for Completion {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.item == other.item
    }
}
impl Eq for Completion {}
impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Completion {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.item.cmp(&self.item))
    }
}

/// Core event loop shared by the trace and DAG replays. `vertex(i)` maps an
/// item to its scope center; `on_complete(i, out)` pushes newly eligible
/// items into `out`.
fn event_loop(
    initial: Vec<u32>,
    vertex: &dyn Fn(u32) -> u32,
    cost: &dyn Fn(u32) -> f64,
    on_complete: &mut dyn FnMut(u32, &mut Vec<u32>),
    locks: &mut LockSim<'_>,
    cfg: &SimConfig,
) -> SimResult {
    let p = cfg.processors.max(1);
    let mut ready: BTreeSet<u32> = initial.into_iter().collect();
    let mut heap: BinaryHeap<Completion> = BinaryHeap::new();

    let mut now = 0.0f64;
    let mut free = p;
    let mut busy_ns = 0.0f64;
    let mut executed = 0usize;
    let mut dispenser_free_at = 0.0f64; // serialized scheduler dispenser
    let mut idle_ns = 0.0f64;
    let mut last_event_time = 0.0f64;
    let overhead = cfg.sched_overhead_ns * (1.0 + cfg.contention_factor * (p as f64 - 1.0));

    // How many ready candidates to test for runnability per free slot. A
    // bounded window keeps the replay near-linear on heavily blocked runs.
    const SCAN_WINDOW: usize = 768;

    loop {
        // Assign free processors to runnable ready tasks (lowest index first).
        let mut assigned_any = true;
        while free > 0 && assigned_any {
            assigned_any = false;
            let mut chosen: Option<u32> = None;
            for &i in ready.iter().take(SCAN_WINDOW) {
                if locks.can_run(vertex(i)) {
                    chosen = Some(i);
                    break;
                }
            }
            if let Some(i) = chosen {
                ready.remove(&i);
                locks.acquire(vertex(i));
                let mut start = now;
                if cfg.sched_serialized {
                    start = start.max(dispenser_free_at);
                    dispenser_free_at = start + cfg.sched_overhead_ns;
                }
                let work = cost(i).max(cfg.min_task_ns);
                heap.push(Completion { time: start + work + overhead, item: i });
                busy_ns += work;
                free -= 1;
                assigned_any = true;
            }
        }

        // Advance to the next completion.
        let Some(done) = heap.pop() else {
            break; // nothing running; ready must be empty (checked below)
        };
        let dt = done.time - last_event_time;
        idle_ns += dt * free as f64;
        last_event_time = done.time;
        now = done.time;
        locks.release(vertex(done.item));
        free += 1;
        executed += 1;
        let mut deliveries = Vec::new();
        on_complete(done.item, &mut deliveries);
        for d in deliveries {
            ready.insert(d);
        }
    }

    debug_assert!(ready.is_empty(), "simulator ended with unrunnable tasks");
    SimResult { processors: p, makespan_ns: now, busy_ns, idle_ns, tasks: executed }
}

/// Replay a captured sequential [`TaskTrace`] on `cfg.processors` virtual
/// processors. `initial` are the tasks seeded before the original run;
/// `neighbors(v)` must describe the graph the trace was captured on.
pub fn simulate_trace(
    trace: &TaskTrace,
    initial: &[Task],
    num_vertices: usize,
    neighbors: &dyn Neighbors,
    cfg: &SimConfig,
) -> SimResult {
    let occ = trace.occurrences(num_vertices);
    let mut delivered = vec![0usize; num_vertices];
    let mut locks = LockSim::new(num_vertices, cfg.model, neighbors);

    // Deliver a spawn of vertex v: eligible iff the trace still has
    // executions of v that were not yet delivered (mirrors de-duplication).
    fn deliver(occ: &[Vec<u32>], delivered: &mut [usize], v: u32, out: &mut Vec<u32>) {
        let k = delivered[v as usize];
        if k < occ[v as usize].len() {
            delivered[v as usize] = k + 1;
            out.push(occ[v as usize][k]);
        }
    }

    let mut first = Vec::new();
    for t in initial {
        deliver(&occ, &mut delivered, t.vertex, &mut first);
    }

    let events = &trace.events;
    let mut on_complete = |i: u32, out: &mut Vec<u32>| {
        for s in &events[i as usize].spawned {
            deliver(&occ, &mut delivered, s.vertex, out);
        }
    };

    event_loop(
        first,
        &|i| events[i as usize].vertex,
        &|i| events[i as usize].cost_ns as f64,
        &mut on_complete,
        &mut locks,
        cfg,
    )
}

/// Replay a set-scheduler [`ExecutionPlan`] DAG (Fig 5). `barrier_mode`
/// executes the literal set-by-set semantics ("plan set scheduler without
/// optimization"); otherwise the DAG partial order is used. `cost(task_idx)`
/// supplies per-task cost in ns.
pub fn simulate_plan(
    plan: &ExecutionPlan,
    num_vertices: usize,
    neighbors: &dyn Neighbors,
    cost: &dyn Fn(u32) -> f64,
    barrier_mode: bool,
    cfg: &SimConfig,
) -> SimResult {
    let n = plan.len();
    let mut locks = LockSim::new(num_vertices, cfg.model, neighbors);

    if barrier_mode {
        let set_of: Vec<u32> = plan.tasks.iter().map(|&(_, _, s)| s).collect();
        let num_sets = set_of.iter().map(|&s| s as usize + 1).max().unwrap_or(0);
        let mut set_members: Vec<Vec<u32>> = vec![Vec::new(); num_sets];
        for (i, &s) in set_of.iter().enumerate() {
            set_members[s as usize].push(i as u32);
        }
        let mut remaining_in_set: Vec<usize> =
            set_members.iter().map(|m| m.len()).collect();
        let first = set_members.first().cloned().unwrap_or_default();
        let mut on_complete = |i: u32, out: &mut Vec<u32>| {
            let s = set_of[i as usize] as usize;
            remaining_in_set[s] -= 1;
            if remaining_in_set[s] == 0 && s + 1 < num_sets {
                out.extend_from_slice(&set_members[s + 1]);
            }
        };
        event_loop(first, &|i| plan.tasks[i as usize].0, cost, &mut on_complete, &mut locks, cfg)
    } else {
        let mut remaining: Vec<u32> = plan.indegree.clone();
        let first: Vec<u32> =
            (0..n as u32).filter(|&i| remaining[i as usize] == 0).collect();
        let mut on_complete = |i: u32, out: &mut Vec<u32>| {
            for &c in plan.children(i) {
                remaining[c as usize] -= 1;
                if remaining[c as usize] == 0 {
                    out.push(c);
                }
            }
        };
        event_loop(first, &|i| plan.tasks[i as usize].0, cost, &mut on_complete, &mut locks, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::trace::TraceEvent;
    use crate::scheduler::set_scheduler::ExecutionPlan;

    fn flat_trace(n: usize, cost: u64) -> TaskTrace {
        TaskTrace {
            initial: vec![],
            events: (0..n)
                .map(|v| TraceEvent {
                    vertex: v as u32,
                    func: 0,
                    priority: 0.0,
                    cost_ns: cost,
                    spawned: vec![],
                })
                .collect(),
        }
    }

    fn no_neighbors() -> Vec<Vec<u32>> {
        Vec::new()
    }

    #[test]
    fn independent_tasks_scale_linearly() {
        let trace = flat_trace(1000, 10_000);
        let initial: Vec<Task> = (0..1000).map(Task::new).collect();
        let cfg1 = SimConfig { sched_overhead_ns: 0.0, min_task_ns: 0.0, ..Default::default() };
        let r1 = simulate_trace(&trace, &initial, 1000, &no_neighbors(), &cfg1);
        let r16 =
            simulate_trace(&trace, &initial, 1000, &no_neighbors(), &cfg1.clone().with_processors(16));
        assert_eq!(r1.tasks, 1000);
        assert_eq!(r16.tasks, 1000);
        let speedup = r1.makespan_ns / r16.makespan_ns;
        assert!((speedup - 16.0).abs() < 0.5, "speedup={speedup}");
        assert!(r16.efficiency() > 0.95);
    }

    #[test]
    fn chain_of_spawns_cannot_scale() {
        // each task spawns the next: pure sequential chain
        let n = 200;
        let mut events = Vec::new();
        for v in 0..n {
            events.push(TraceEvent {
                vertex: v as u32,
                func: 0,
                priority: 0.0,
                cost_ns: 1000,
                spawned: if v + 1 < n { vec![Task::new((v + 1) as u32)] } else { vec![] },
            });
        }
        let trace = TaskTrace { initial: vec![], events };
        let cfg = SimConfig { sched_overhead_ns: 0.0, min_task_ns: 0.0, ..Default::default() };
        let r1 = simulate_trace(&trace, &[Task::new(0)], n, &no_neighbors(), &cfg);
        let r8 = simulate_trace(
            &trace,
            &[Task::new(0)],
            n,
            &no_neighbors(),
            &cfg.clone().with_processors(8),
        );
        assert!(
            (r1.makespan_ns / r8.makespan_ns - 1.0).abs() < 0.01,
            "chains don't parallelize"
        );
    }

    #[test]
    fn dedup_matches_execution_counts() {
        // vertex 1 is spawned by both 0 and 2 but executed once in the trace:
        // the second spawn must be dropped.
        let events = vec![
            TraceEvent { vertex: 0, func: 0, priority: 0.0, cost_ns: 100, spawned: vec![Task::new(1)] },
            TraceEvent { vertex: 2, func: 0, priority: 0.0, cost_ns: 100, spawned: vec![Task::new(1)] },
            TraceEvent { vertex: 1, func: 0, priority: 0.0, cost_ns: 100, spawned: vec![] },
        ];
        let trace = TaskTrace { initial: vec![], events };
        let cfg = SimConfig { sched_overhead_ns: 0.0, min_task_ns: 0.0, ..Default::default() }
            .with_processors(4);
        let r = simulate_trace(&trace, &[Task::new(0), Task::new(2)], 3, &no_neighbors(), &cfg);
        assert_eq!(r.tasks, 3, "every trace event executes exactly once");
    }

    #[test]
    fn edge_vs_full_consistency_on_a_star() {
        // star: hub 0 with 8 leaves; tasks center on leaves. Edge model:
        // leaves read-lock the hub -> all run concurrently. Full model:
        // leaves write-lock the hub -> serial.
        let leaves = 8usize;
        let nb: Vec<Vec<u32>> = std::iter::once((1..=leaves as u32).collect::<Vec<_>>())
            .chain((0..leaves).map(|_| vec![0u32]))
            .collect();

        let trace = TaskTrace {
            initial: vec![],
            events: (1..=leaves as u32)
                .map(|v| TraceEvent {
                    vertex: v,
                    func: 0,
                    priority: 0.0,
                    cost_ns: 10_000,
                    spawned: vec![],
                })
                .collect(),
        };
        let initial: Vec<Task> = (1..=leaves as u32).map(Task::new).collect();
        let base = SimConfig { sched_overhead_ns: 0.0, min_task_ns: 0.0, ..Default::default() };

        let edge = simulate_trace(
            &trace,
            &initial,
            leaves + 1,
            &nb,
            &base.clone().with_processors(8).with_model(ConsistencyModel::Edge),
        );
        let full = simulate_trace(
            &trace,
            &initial,
            leaves + 1,
            &nb,
            &base.clone().with_processors(8).with_model(ConsistencyModel::Full),
        );
        assert!(
            edge.makespan_ns * 6.0 < full.makespan_ns,
            "full consistency serializes the star: edge={} full={}",
            edge.makespan_ns,
            full.makespan_ns
        );
    }

    #[test]
    fn serialized_dispatch_caps_throughput() {
        let trace = flat_trace(1000, 100); // tiny tasks
        let initial: Vec<Task> = (0..1000).map(Task::new).collect();
        let strict = SimConfig {
            sched_overhead_ns: 500.0,
            sched_serialized: true,
            min_task_ns: 0.0,
            processors: 16,
            model: ConsistencyModel::Vertex,
            contention_factor: 0.0,
        };
        let relaxed = SimConfig { sched_serialized: false, ..strict.clone() };
        let rs = simulate_trace(&trace, &initial, 1000, &no_neighbors(), &strict);
        let rr = simulate_trace(&trace, &initial, 1000, &no_neighbors(), &relaxed);
        assert!(
            rs.makespan_ns > rr.makespan_ns * 2.0,
            "global dispenser must bottleneck tiny tasks: strict={} relaxed={}",
            rs.makespan_ns,
            rr.makespan_ns
        );
    }

    #[test]
    fn plan_dag_beats_barrier() {
        // 10 sets of 10 independent vertices; each set contains one straggler
        // task (10x cost). The barrier mode stalls the whole machine on every
        // set's straggler; the plan (no cross-set data deps here) lets Graham
        // list scheduling overlap sets freely — the Fig 5a/c effect.
        let num_sets = 10u32;
        let per_set = 10u32;
        let n = (num_sets * per_set) as usize;
        let nb: Vec<Vec<u32>> = vec![Vec::new(); n];
        let sets: Vec<(Vec<u32>, u32)> = (0..num_sets)
            .map(|s| ((s * per_set..(s + 1) * per_set).collect(), 0))
            .collect();
        let plan =
            ExecutionPlan::compile(&sets, n, |v| nb[v as usize].as_slice(), ConsistencyModel::Edge);
        let cost = |i: u32| if i % per_set == 0 { 10_000.0 } else { 1_000.0 };
        let cfg = SimConfig { sched_overhead_ns: 0.0, min_task_ns: 0.0, ..Default::default() }
            .with_processors(4)
            .with_model(ConsistencyModel::Vertex);
        let planned = simulate_plan(&plan, n, &nb, &cost, false, &cfg);
        let barrier = simulate_plan(&plan, n, &nb, &cost, true, &cfg);
        assert_eq!(planned.tasks, n);
        assert_eq!(barrier.tasks, n);
        assert!(
            planned.makespan_ns < barrier.makespan_ns * 0.7,
            "plan optimization must hide stragglers: planned={} barrier={}",
            planned.makespan_ns,
            barrier.makespan_ns
        );
        assert!(planned.efficiency() > barrier.efficiency());
    }

    #[test]
    fn speedup_curve_normalizes_to_p1() {
        let curve = speedup_curve(&[(1, 100.0), (2, 50.0), (4, 30.0)]);
        assert_eq!(curve[0], (1, 1.0));
        assert_eq!(curve[1], (2, 2.0));
        assert!((curve[2].1 - 100.0 / 30.0).abs() < 1e-9);
    }

    #[test]
    fn more_processors_never_slower() {
        // random-ish spawn structure
        let mut events = Vec::new();
        for v in 0..500u32 {
            let spawned = if v < 400 {
                vec![Task::new((v + 50) % 300), Task::new((v + 100) % 300)]
            } else {
                vec![]
            };
            events.push(TraceEvent {
                vertex: v % 300,
                func: 0,
                priority: 0.0,
                cost_ns: 500 + (v as u64 * 37) % 3000,
                spawned,
            });
        }
        let trace = TaskTrace { initial: vec![], events };
        let initial: Vec<Task> = (0..300).map(Task::new).collect();
        let cfg = SimConfig::default().with_model(ConsistencyModel::Vertex);
        let mut prev = f64::INFINITY;
        for p in [1, 2, 4, 8, 16] {
            let r = simulate_trace(
                &trace,
                &initial,
                300,
                &no_neighbors(),
                &cfg.clone().with_processors(p),
            );
            assert!(
                r.makespan_ns <= prev * 1.001,
                "P={p} slower: {} vs {}",
                r.makespan_ns,
                prev
            );
            prev = r.makespan_ns;
        }
    }
}
