//! Micro-benchmarks of the framework hot paths — the §Perf baseline
//! (EXPERIMENTS.md). Measures, per layer-3 component:
//!
//! * scheduler add/pop throughput per scheduler type;
//! * **multi-threaded scheduler throughput** (tasks/sec at 1/2/4/8
//!   workers): the lock-free sharded schedulers vs their `Mutex<VecDeque>`
//!   / `Mutex<BinaryHeap>` strict baselines, plus an injector
//!   ring-capacity sweep — results/BENCH_sched.json;
//! * **vertex storage** (SoA slab vs Vec-of-struct): BP belief-sweep and
//!   delta-capture throughput — joins results/BENCH_shard.json;
//! * scope lock acquisition per consistency model and degree;
//! * the atomic lock table itself: uncontended vs conflicted try-acquire
//!   (the conflict path measures the cost of a failed all-or-nothing
//!   acquisition including rollback — the price of a deferral) and the
//!   per-vertex memory footprint vs the old `RwLock<()>` table;
//! * end-to-end engine overhead per trivial update (1..4 workers);
//! * **telemetry overhead**: the same threaded run with event rings +
//!   sampler off vs on (CI gates on within 5% of off) —
//!   results/BENCH_telemetry.json;
//! * ghost-sync transport throughput: deltas/sec and bytes per delta for
//!   the direct vs serialized-channel (raw and compressed "channel-z") vs
//!   shared-memory SPSC ring ("shm") vs unix-socket (raw and compressed
//!   "socket-z") backends at batch windows {1,16,64} —
//!   results/BENCH_transport.json;
//! * PJRT batched-kernel dispatch latency (if artifacts are built).
//!
//! Output: bench table on stdout + results/micro.tsv +
//! results/BENCH_locks.json + results/BENCH_sched.json +
//! results/BENCH_transport.json + results/BENCH_telemetry.json.

use graphlab::consistency::{ConsistencyModel, LockTable, Scope};
use graphlab::engine::{Program, UpdateContext, UpdateFn};
use graphlab::graph::{DataGraph, GraphBuilder, ShardedGraph};
use graphlab::scheduler::{
    by_name, ApproxPriorityScheduler, FifoScheduler, MultiQueueFifo, PriorityScheduler,
    Scheduler, Task,
};
use graphlab::sdt::Sdt;
use graphlab::util::timer::{bench, bench_header, fmt_secs, BenchResult};
use graphlab::util::Timer;
use std::io::Write as _;

/// Multi-threaded scheduler throughput: `workers` threads each seed a
/// private vertex range, then run pop → re-add cycles against the shared
/// scheduler until they complete a fixed iteration budget. Returns
/// delivered tasks/sec (pops across all workers / wall time).
fn sched_throughput(sched: &dyn Scheduler, workers: usize, iters_per_worker: u32) -> f64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    const VERTS_PER_WORKER: u32 = 2048;
    let total = AtomicU64::new(0);
    let timer = Timer::start();
    std::thread::scope(|s| {
        for w in 0..workers {
            let total = &total;
            s.spawn(move || {
                let base = w as u32 * VERTS_PER_WORKER;
                for v in 0..VERTS_PER_WORKER {
                    sched.add_task(Task::with_priority(base + v, ((v % 97) + 1) as f64));
                }
                let mut count = 0u64;
                while count < iters_per_worker as u64 {
                    if let Some(t) = sched.next_task(w) {
                        count += 1;
                        sched.add_task(Task::with_priority(
                            t.vertex,
                            ((t.vertex % 97) + 1) as f64,
                        ));
                    } else {
                        std::thread::yield_now();
                    }
                }
                total.fetch_add(count, Ordering::Relaxed);
            });
        }
    });
    total.load(Ordering::Relaxed) as f64 / timer.elapsed_secs().max(1e-12)
}

/// Row-major 2D grid (the sharding rows cut it into contiguous row bands).
fn grid2d(side: u32) -> DataGraph<u64, ()> {
    let mut b = GraphBuilder::new();
    for _ in 0..side * side {
        b.add_vertex(0u64);
    }
    for y in 0..side {
        for x in 0..side {
            let v = y * side + x;
            if x + 1 < side {
                b.add_undirected(v, v + 1, (), ());
            }
            if y + 1 < side {
                b.add_undirected(v, v + side, (), ());
            }
        }
    }
    b.build()
}

fn ring(n: usize, degree: usize) -> DataGraph<u64, ()> {
    let mut b = GraphBuilder::new();
    for _ in 0..n {
        b.add_vertex(0u64);
    }
    for i in 0..n {
        for d in 1..=degree / 2 {
            b.add_undirected(i as u32, ((i + d) % n) as u32, (), ());
        }
    }
    b.build()
}

fn main() {
    let mut rows: Vec<BenchResult> = Vec::new();
    println!("{}", bench_header());
    let mut push = |r: BenchResult| {
        println!("{}", r.row());
        rows.push(r);
    };

    // ---- scheduler ops ----------------------------------------------------
    let n = 100_000;
    for name in ["fifo", "multiqueue", "partitioned", "priority-strict", "approx-priority"] {
        let sched = by_name(name, n, 4).unwrap();
        let r = bench(&format!("sched/{name}/add+pop x10k"), 3, 30, || {
            for v in 0..10_000u32 {
                sched.add_task(Task::with_priority(v, (v % 97) as f64));
            }
            // cycle worker ids: worker-affine schedulers (partitioned) only
            // serve their own partition
            let mut popped = 0;
            let mut idle = 0;
            let mut w = 0usize;
            while idle < 4 {
                if sched.next_task(w).is_some() {
                    popped += 1;
                    idle = 0;
                } else {
                    idle += 1;
                    w = (w + 1) % 4;
                }
            }
            assert_eq!(popped, 10_000);
        });
        push(r);
    }

    // ---- scheduler throughput: lock-free vs mutex baselines -----------------
    //
    // The headline of the task-distribution rework: sharded lock-free
    // schedulers (injector rings + owner-affine routing) against the strict
    // mutex-serialized baselines, across worker counts. The lock-free FIFO
    // path should pull ahead of the `Mutex<VecDeque>` baseline at >= 4
    // workers; machine-readable copy in results/BENCH_sched.json.
    let mut sched_json: Vec<(String, f64)> = Vec::new();
    {
        let iters: u32 = 50_000;
        println!(
            "{:<44} {:>12} (pop+re-add cycles, tasks/sec)",
            "sched-throughput", "tasks/s"
        );
        for workers in [1usize, 2, 4, 8] {
            let n = workers * 2048;
            let configs: Vec<(&str, Box<dyn Scheduler>)> = vec![
                ("fifo_mutex", Box::new(FifoScheduler::new(n))),
                ("fifo_lockfree", Box::new(MultiQueueFifo::new(n, workers))),
                ("priority_mutex", Box::new(PriorityScheduler::new(n))),
                (
                    "priority_lockfree",
                    Box::new(ApproxPriorityScheduler::new(n, workers)),
                ),
            ];
            for (label, sched) in &configs {
                let tps = sched_throughput(sched.as_ref(), workers, iters);
                println!(
                    "{:<44} {:>12.0}",
                    format!("sched-throughput/{label}/{workers}w"),
                    tps
                );
                sched_json.push((format!("{label}_w{workers}_tasks_per_sec"), tps));
            }
        }
    }

    // ---- injector ring-capacity sweep ---------------------------------------
    //
    // The MPMC injector ring degrades gracefully when the in-flight task set
    // outgrows its capacity (overflow spills to a mutexed deque); this sweep
    // pins where the knee sits for a fixed 4096-task working set so the
    // engine's capacity hint can be judged against data.
    {
        use graphlab::scheduler::Injector;
        use std::sync::atomic::{AtomicU64, Ordering};
        let workers = 4usize;
        let live = 4096u32;
        let iters_per_worker = 200_000u64;
        for cap in [64usize, 512, 4096, 65_536] {
            let inj: Injector<Task> = Injector::new(cap);
            for v in 0..live {
                inj.push(Task::new(v));
            }
            let total = AtomicU64::new(0);
            let timer = Timer::start();
            std::thread::scope(|s| {
                for _ in 0..workers {
                    let inj = &inj;
                    let total = &total;
                    s.spawn(move || {
                        let mut count = 0u64;
                        while count < iters_per_worker {
                            if let Some(t) = inj.pop() {
                                count += 1;
                                inj.push(t);
                            } else {
                                std::thread::yield_now();
                            }
                        }
                        total.fetch_add(count, Ordering::Relaxed);
                    });
                }
            });
            let tps = total.load(Ordering::Relaxed) as f64 / timer.elapsed_secs().max(1e-12);
            println!(
                "{:<44} {:>12.0} (4096 live tasks, {workers} workers)",
                format!("sched-throughput/injector/cap{}", inj.capacity()),
                tps
            );
            sched_json.push((format!("injector_cap{}_tasks_per_sec", inj.capacity()), tps));
        }
    }

    // ---- scope locking ------------------------------------------------------
    for degree in [4usize, 16] {
        let g = ring(4096, degree);
        let locks = LockTable::new(4096);
        for model in
            [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full]
        {
            let r = bench(
                &format!("scope/{}/deg{degree} x4096", model.name()),
                3,
                30,
                || {
                    for v in 0..4096u32 {
                        let s = Scope::lock(&g, &locks, v, model);
                        std::hint::black_box(s.center());
                    }
                },
            );
            push(r);
        }
    }

    // ---- lock table: try-acquire fast path and conflict/rollback path ------
    let mut lock_json: Vec<(String, f64)> = Vec::new();
    {
        let g = ring(4096, 4);
        let locks = LockTable::new(4096);
        let r = bench("locktable/try-acquire/uncontended x4096", 3, 30, || {
            for v in 0..4096u32 {
                let Ok(guard) =
                    locks.try_lock_scope(v, g.lock_neighbors(v), ConsistencyModel::Full)
                else {
                    unreachable!("uncontended acquire cannot conflict")
                };
                std::hint::black_box(&guard);
            }
        });
        lock_json.push(("uncontended_full_scope_ns".into(), r.summary.mean * 1e9 / 4096.0));
        push(r);

        // Guaranteed conflict: pre-hold a write lock on one vertex, then
        // try-acquire every scope that includes it. Measures detection +
        // rollback, i.e. the fixed cost the engine pays before deferring.
        let Ok(held) = locks.try_lock_scope(0, &[], ConsistencyModel::Vertex) else {
            unreachable!("free table")
        };
        let contenders: Vec<u32> = g.neighbors(0).to_vec();
        let r = bench("locktable/try-acquire/conflict+rollback", 3, 30, || {
            for _ in 0..1024 {
                for &v in &contenders {
                    let res =
                        locks.try_lock_scope(v, g.lock_neighbors(v), ConsistencyModel::Full);
                    assert!(res.is_err(), "scope overlapping a held lock must conflict");
                }
            }
        });
        lock_json
            .push(("conflict_rollback_ns".into(), r.summary.mean * 1e9 / (1024.0 * contenders.len() as f64)));
        push(r);
        drop(held);

        // Memory: the tentpole claim — one 32-bit word per vertex.
        let atomic_bytes = LockTable::bytes_per_vertex();
        let rwlock_bytes = std::mem::size_of::<std::sync::RwLock<()>>();
        println!(
            "{:<44} {:>12} (vs {} B/vertex for std RwLock<()> — {:.1}x smaller)",
            "locktable/bytes-per-vertex",
            format!("{atomic_bytes} B"),
            rwlock_bytes,
            rwlock_bytes as f64 / atomic_bytes as f64
        );
        lock_json.push(("bytes_per_vertex_atomic".into(), atomic_bytes as f64));
        lock_json.push(("bytes_per_vertex_rwlock".into(), rwlock_bytes as f64));
    }

    // ---- engine per-update overhead ----------------------------------------
    struct Noop;
    impl UpdateFn<u64, ()> for Noop {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, _ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
        }
    }
    let noop = Noop;
    for workers in [1usize, 2, 4] {
        let mut g = ring(65_536, 4);
        let sdt = Sdt::new();
        let sched = MultiQueueFifo::new(65_536, workers);
        let timer = Timer::start();
        for v in 0..65_536u32 {
            sched.add_task(Task::new(v));
        }
        let report = Program::new()
            .update_fn(&noop)
            .workers(workers)
            .model(ConsistencyModel::Edge)
            // explicit back-end: measure the threaded loop even at 1 worker
            .run_on(&graphlab::engine::ThreadedEngine, &mut g, &sched, &sdt);
        let per_task = timer.elapsed_secs() / report.updates as f64;
        println!(
            "{:<44} {:>12} (engine trivial-update cost, {} workers, {} conflicts)",
            format!("engine/noop/{workers}w"),
            fmt_secs(per_task),
            workers,
            report.contention.conflicts
        );
    }

    // throughput with a single queue for contrast
    {
        let mut g = ring(65_536, 4);
        let sdt = Sdt::new();
        let program = Program::new()
            .update_fn(&noop)
            .workers(2)
            .model(ConsistencyModel::Edge);
        let sched = FifoScheduler::new(65_536);
        for v in 0..65_536u32 {
            sched.add_task(Task::new(v));
        }
        let timer = Timer::start();
        let report = program.run(&mut g, &sched, &sdt);
        println!(
            "{:<44} {:>12} (strict single-queue, 2 workers)",
            "engine/noop/fifo-2w",
            fmt_secs(timer.elapsed_secs() / report.updates as f64)
        );
        // priority scheduler contrast
        let sched = PriorityScheduler::new(65_536);
        for v in 0..65_536u32 {
            sched.add_task(Task::with_priority(v, (v % 13) as f64));
        }
        let timer = Timer::start();
        let report = program.run(&mut g, &sched, &sdt);
        println!(
            "{:<44} {:>12} (strict priority heap, 2 workers)",
            "engine/noop/priority-2w",
            fmt_secs(timer.elapsed_secs() / report.updates as f64)
        );
    }

    // ---- telemetry overhead -------------------------------------------------
    //
    // The observability gate: the same threaded run with and without a
    // `TelemetryConfig`. Disabled, every emit point is one thread-local
    // read and a branch; enabled, a task span costs two clock reads and a
    // ring write. Measured on an update with a small real compute kernel
    // (a pure no-op would price the probes against nothing). CI gates the
    // enabled run at >= 95% of the disabled throughput —
    // results/BENCH_telemetry.json.
    let mut telemetry_json: Vec<(String, f64)> = Vec::new();
    {
        use graphlab::telemetry::TelemetryConfig;
        struct SmallKernel;
        impl UpdateFn<u64, ()> for SmallKernel {
            fn update(&self, scope: &mut Scope<'_, u64, ()>, _ctx: &mut UpdateContext<'_>) {
                // A handful of LCG steps: enough arithmetic to resemble a
                // cheap real update, small enough to stay probe-sensitive.
                let mut acc = *scope.vertex() | 1;
                for _ in 0..16 {
                    acc = acc
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1442695040888963407);
                }
                *scope.vertex_mut() = acc;
            }
        }
        let kernel = SmallKernel;
        let run = |telemetry: bool| -> f64 {
            let n = 65_536usize;
            let mut g = ring(n, 4);
            let sched = MultiQueueFifo::new(n, 4);
            for v in 0..n as u32 {
                sched.add_task(Task::new(v));
            }
            let mut program =
                Program::new().update_fn(&kernel).workers(4).model(ConsistencyModel::Edge);
            if telemetry {
                program = program.telemetry(TelemetryConfig::default());
            }
            let timer = Timer::start();
            let report = program.run_on(
                &graphlab::engine::ThreadedEngine,
                &mut g,
                &sched,
                &Sdt::new(),
            );
            report.updates as f64 / timer.elapsed_secs().max(1e-12)
        };
        run(false); // warm the allocator and the page cache
        let off = run(false);
        let on = run(true);
        println!("{:<44} {:>12.0} (telemetry disabled)", "telemetry/off/4w", off);
        println!(
            "{:<44} {:>12.0} ({:+.1}% vs off)",
            "telemetry/on/4w",
            on,
            100.0 * (on - off) / off.max(1e-12)
        );
        telemetry_json.push(("telemetry_off_tasks_per_sec".into(), off));
        telemetry_json.push(("telemetry_on_tasks_per_sec".into(), on));
    }

    // ---- sharding: edge-cut ratio + ghost-sync throughput -------------------
    //
    // The sharded-graph layer's two cost drivers: how many edges a k-way
    // contiguous-block cut severs (replication factor) and how fast the
    // versioned ghost tables absorb a full sync pass (the emulated network
    // flush). Machine-readable copy in results/BENCH_shard.json.
    let mut shard_json: Vec<(String, f64)> = Vec::new();
    {
        let side = 64u32;
        println!(
            "{:<44} {:>12} {:>14} {:>16}",
            "shard", "cut-ratio", "ghosts", "ghost-syncs/s"
        );
        for k in [1usize, 2, 4, 8] {
            let mut g = grid2d(side);
            let n = g.num_vertices();
            let sharded = ShardedGraph::new(&mut g, k);
            let locks = LockTable::new(n);
            // warm + measure full sync passes
            sharded.sync_all(&g, &locks);
            let iters = 50u32;
            let timer = Timer::start();
            let mut wrote = 0u64;
            for _ in 0..iters {
                let (_vertices, replicas) = sharded.sync_all(&g, &locks);
                wrote += replicas;
            }
            let secs = timer.elapsed_secs().max(1e-12);
            let rate = wrote as f64 / secs;
            println!(
                "{:<44} {:>12.4} {:>14} {:>16.0}",
                format!("shard/grid{side}x{side}/k{k}"),
                sharded.cut_ratio(),
                sharded.num_ghosts(),
                rate
            );
            shard_json.push((format!("edge_cut_ratio_k{k}"), sharded.cut_ratio()));
            shard_json.push((format!("ghosts_k{k}"), sharded.num_ghosts() as f64));
            shard_json.push((format!("ghost_syncs_per_sec_k{k}"), rate));
        }
    }

    // ---- vertex storage: SoA slab vs Vec-of-struct --------------------------
    //
    // The flat-storage tentpole, measured head-to-head on the BP vertex
    // payload (K=3): a belief-update sweep (the BP inner-loop memory access
    // pattern) and a delta capture (what clone-under-lock costs) on the
    // contiguous `FlatVertexStore` slabs vs a `Vec<BpVertex>` of heap
    // `Vec<f32>` fields. Machine-readable rows join BENCH_shard.json.
    {
        use graphlab::apps::mrf::BpVertex;
        use graphlab::graph::FlatVertexStore;
        let n = 65_536usize;
        let k = 3usize;
        let mk = |i: usize| BpVertex {
            potential: vec![0.3, 0.4, 0.3],
            belief: vec![1.0 + (i % 7) as f32, 1.0, 2.0],
            observed: u32::MAX,
            axis_stats: [0.0; 3],
        };
        let mut aos: Vec<BpVertex> = (0..n).map(mk).collect();
        let mut soa: FlatVertexStore<BpVertex> = FlatVertexStore::new(k, n);
        for v in 0..n {
            soa.set(v as u32, &aos[v]);
        }
        let sweeps = 30u64;
        println!(
            "{:<44} {:>12} (BP belief sweep, K={k}, {n} vertices)",
            "storage", "verts/s"
        );

        let timer = Timer::start();
        for _ in 0..sweeps {
            for v in aos.iter_mut() {
                let mut sum = 0.0f32;
                for j in 0..k {
                    v.belief[j] = v.potential[j] * (v.belief[j] + 1.0);
                    sum += v.belief[j];
                }
                let inv = 1.0 / sum;
                for j in 0..k {
                    v.belief[j] *= inv;
                }
            }
        }
        let vec_update = (sweeps * n as u64) as f64 / timer.elapsed_secs().max(1e-12);
        println!("{:<44} {:>12.0}", "storage/update/vec", vec_update);

        let timer = Timer::start();
        for _ in 0..sweeps {
            for v in 0..n as u32 {
                let (floats, _) = soa.row_mut(v);
                let (pot, rest) = floats.split_at_mut(k);
                let belief = &mut rest[..k];
                let mut sum = 0.0f32;
                for j in 0..k {
                    belief[j] = pot[j] * (belief[j] + 1.0);
                    sum += belief[j];
                }
                let inv = 1.0 / sum;
                for j in 0..k {
                    belief[j] *= inv;
                }
            }
        }
        let soa_update = (sweeps * n as u64) as f64 / timer.elapsed_secs().max(1e-12);
        println!("{:<44} {:>12.0}", "storage/update/soa", soa_update);

        // Delta capture: what the engine pays per boundary write to snapshot
        // vertex data under the lock. Vec-of-struct reuses a slot via
        // clone_from (still two heap-buffer copies + bookkeeping); the slab
        // row copy is two contiguous memcpys.
        let mut snapshot = mk(0);
        let timer = Timer::start();
        for _ in 0..sweeps {
            for v in aos.iter() {
                snapshot.clone_from(v);
                std::hint::black_box(&snapshot);
            }
        }
        let vec_capture = (sweeps * n as u64) as f64 / timer.elapsed_secs().max(1e-12);
        println!("{:<44} {:>12.0}", "storage/capture/vec-clone", vec_capture);

        let mut shadow: FlatVertexStore<BpVertex> = FlatVertexStore::new(k, n);
        let timer = Timer::start();
        for _ in 0..sweeps {
            for v in 0..n as u32 {
                shadow.copy_row_from(v, &soa, v);
            }
        }
        let soa_capture = (sweeps * n as u64) as f64 / timer.elapsed_secs().max(1e-12);
        println!("{:<44} {:>12.0}", "storage/capture/soa-row", soa_capture);

        shard_json.push(("vec_update_verts_per_sec".into(), vec_update));
        shard_json.push(("soa_update_verts_per_sec".into(), soa_update));
        shard_json.push(("vec_clone_capture_per_sec".into(), vec_capture));
        shard_json.push(("soa_row_capture_per_sec".into(), soa_capture));
    }

    // ---- transport: Direct vs Channel vs Socket across batch windows --------
    //
    // The ghost-sync transport layer's cost drivers: deltas/sec through the
    // batcher + backend, and bytes shipped per delta (zero for the direct
    // in-memory backend; the serialized frame size for the channel and
    // unix-socket backends — the socket rows additionally pay the kernel
    // syscall path and the reader-thread hop before a drain can apply).
    // Machine-readable copy in results/BENCH_transport.json.
    let mut transport_json: Vec<(String, f64)> = Vec::new();
    {
        use graphlab::transport::{
            ChannelTransport, DeltaBatcher, DirectTransport, GhostTransport, ShmTransport,
            SocketTransport,
        };
        let side = 64u32;
        let mut g = grid2d(side);
        let k = 4usize;
        let sharded = ShardedGraph::new(&mut g, k);
        // boundary vertices grouped by owning shard (a worker's batcher only
        // ever records vertices of its own shard)
        let mut by_shard: Vec<Vec<u32>> = vec![Vec::new(); k];
        for v in 0..sharded.num_vertices() as u32 {
            if !sharded.replicas_of(v).is_empty() {
                by_shard[sharded.owner_of(v)].push(v);
            }
        }
        println!(
            "{:<44} {:>12} {:>14}",
            "transport", "deltas/s", "bytes/delta"
        );
        for backend in ["direct", "channel", "channel-z", "shm", "socket", "socket-z"] {
            for batch in [1usize, 16, 64] {
                let transport: Box<dyn GhostTransport<u64> + '_> = match backend {
                    "direct" => Box::new(DirectTransport::new(&sharded)),
                    "channel" => Box::new(ChannelTransport::new(&sharded)),
                    "channel-z" => Box::new(ChannelTransport::compressed(&sharded)),
                    "shm" => Box::new(ShmTransport::new(&sharded)),
                    "socket-z" => Box::new(
                        SocketTransport::compressed(&sharded)
                            .expect("unix-socket transport setup"),
                    ),
                    _ => Box::new(
                        SocketTransport::new(&sharded)
                            .expect("unix-socket transport setup"),
                    ),
                };
                let rounds = 200u64;
                let timer = Timer::start();
                let mut deltas = 0u64;
                let mut bytes = 0u64;
                for round in 0..rounds {
                    for (shard, owned) in by_shard.iter().enumerate() {
                        let mut batcher: DeltaBatcher<u64> = DeltaBatcher::new(batch);
                        for &v in owned {
                            let ver = sharded.bump_master(v);
                            batcher.record(v, ver, &round);
                            if batcher.should_flush() {
                                let r = batcher.flush(shard, transport.as_ref());
                                deltas += r.deltas;
                                bytes += r.bytes;
                            }
                        }
                        if !batcher.is_empty() {
                            let r = batcher.flush(shard, transport.as_ref());
                            deltas += r.deltas;
                            bytes += r.bytes;
                        }
                    }
                    for shard in 0..k {
                        transport.drain(shard);
                    }
                }
                // Asynchronous backends: charge full delivery (reader
                // threads + kernel buffers) to the measured window.
                transport.finalize();
                for shard in 0..k {
                    transport.drain(shard);
                }
                let secs = timer.elapsed_secs().max(1e-12);
                let dps = deltas as f64 / secs;
                let bpd = bytes as f64 / deltas.max(1) as f64;
                println!(
                    "{:<44} {:>12.0} {:>14.1}",
                    format!("transport/{backend}/b{batch}"),
                    dps,
                    bpd
                );
                transport_json.push((format!("{backend}_b{batch}_deltas_per_sec"), dps));
                transport_json.push((format!("{backend}_b{batch}_bytes_per_delta"), bpd));
            }
        }
    }

    // ---- PJRT dispatch ------------------------------------------------------
    let dir = graphlab::runtime::default_artifact_dir();
    if dir.join("manifest.tsv").exists() {
        let mut reg = graphlab::runtime::ArtifactRegistry::open(&dir).unwrap();
        for name in ["bp_batch_b256_k5", "bp_batch_b1024_k5", "gabp_batch_b4096"] {
            let exe = reg.load(name).unwrap();
            let inputs: Vec<Vec<f32>> =
                exe.meta.inputs.iter().map(|s| vec![0.5f32; s.elements()]).collect();
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let r = bench(&format!("pjrt/{name}"), 3, 50, || {
                exe.run_f32(&refs).unwrap();
            });
            push(r);
        }
    } else {
        println!("(skipping PJRT rows: run `make artifacts`)");
    }

    // TSV dump
    std::fs::create_dir_all("results").unwrap();
    let mut f = std::fs::File::create("results/micro.tsv").unwrap();
    writeln!(f, "benchmark\tmean_s\tstddev_s\tp50_s\tp95_s").unwrap();
    for r in &rows {
        writeln!(
            f,
            "{}\t{}\t{}\t{}\t{}",
            r.name, r.summary.mean, r.summary.stddev, r.summary.p50, r.summary.p95
        )
        .unwrap();
    }
    println!("wrote results/micro.tsv");

    // Lock-table JSON (the measurable tentpole win, machine-readable).
    let mut f = std::fs::File::create("results/BENCH_locks.json").unwrap();
    writeln!(f, "{{").unwrap();
    for (i, (key, value)) in lock_json.iter().enumerate() {
        let comma = if i + 1 == lock_json.len() { "" } else { "," };
        writeln!(f, "  \"{key}\": {value:.3}{comma}").unwrap();
    }
    writeln!(f, "}}").unwrap();
    println!("wrote results/BENCH_locks.json");

    // Scheduler-throughput JSON (lock-free vs mutex, per worker count).
    let mut f = std::fs::File::create("results/BENCH_sched.json").unwrap();
    writeln!(f, "{{").unwrap();
    for (i, (key, value)) in sched_json.iter().enumerate() {
        let comma = if i + 1 == sched_json.len() { "" } else { "," };
        writeln!(f, "  \"{key}\": {value:.0}{comma}").unwrap();
    }
    writeln!(f, "}}").unwrap();
    println!("wrote results/BENCH_sched.json");

    // Sharding JSON (edge-cut ratios + ghost-sync throughput per k).
    let mut f = std::fs::File::create("results/BENCH_shard.json").unwrap();
    writeln!(f, "{{").unwrap();
    for (i, (key, value)) in shard_json.iter().enumerate() {
        let comma = if i + 1 == shard_json.len() { "" } else { "," };
        writeln!(f, "  \"{key}\": {value:.4}{comma}").unwrap();
    }
    writeln!(f, "}}").unwrap();
    println!("wrote results/BENCH_shard.json");

    // Transport JSON (Direct vs Channel deltas/sec + bytes per delta, per
    // batch window).
    let mut f = std::fs::File::create("results/BENCH_transport.json").unwrap();
    writeln!(f, "{{").unwrap();
    for (i, (key, value)) in transport_json.iter().enumerate() {
        let comma = if i + 1 == transport_json.len() { "" } else { "," };
        writeln!(f, "  \"{key}\": {value:.1}{comma}").unwrap();
    }
    writeln!(f, "}}").unwrap();
    println!("wrote results/BENCH_transport.json");

    // Telemetry overhead JSON (off vs on tasks/sec; CI gates on <= 5%).
    let mut f = std::fs::File::create("results/BENCH_telemetry.json").unwrap();
    writeln!(f, "{{").unwrap();
    for (i, (key, value)) in telemetry_json.iter().enumerate() {
        let comma = if i + 1 == telemetry_json.len() { "" } else { "," };
        writeln!(f, "  \"{key}\": {value:.0}{comma}").unwrap();
    }
    writeln!(f, "}}").unwrap();
    println!("wrote results/BENCH_telemetry.json");
}
