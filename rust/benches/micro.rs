//! Micro-benchmarks of the framework hot paths — the §Perf baseline
//! (EXPERIMENTS.md). Measures, per layer-3 component:
//!
//! * scheduler add/pop throughput per scheduler type;
//! * scope lock acquisition per consistency model and degree;
//! * end-to-end engine overhead per trivial update (1..4 workers);
//! * PJRT batched-kernel dispatch latency (if artifacts are built).
//!
//! Output: bench table on stdout + results/micro.tsv.

use graphlab::consistency::{ConsistencyModel, LockTable, Scope};
use graphlab::engine::{EngineConfig, ThreadedEngine, UpdateContext, UpdateFn};
use graphlab::graph::{DataGraph, GraphBuilder};
use graphlab::scheduler::{
    by_name, FifoScheduler, MultiQueueFifo, PriorityScheduler, Scheduler, Task,
};
use graphlab::sdt::Sdt;
use graphlab::util::timer::{bench, bench_header, fmt_secs, BenchResult};
use graphlab::util::Timer;
use std::io::Write as _;

fn ring(n: usize, degree: usize) -> DataGraph<u64, ()> {
    let mut b = GraphBuilder::new();
    for _ in 0..n {
        b.add_vertex(0u64);
    }
    for i in 0..n {
        for d in 1..=degree / 2 {
            b.add_undirected(i as u32, ((i + d) % n) as u32, (), ());
        }
    }
    b.build()
}

fn main() {
    let mut rows: Vec<BenchResult> = Vec::new();
    println!("{}", bench_header());
    let mut push = |r: BenchResult| {
        println!("{}", r.row());
        rows.push(r);
    };

    // ---- scheduler ops ----------------------------------------------------
    let n = 100_000;
    for name in ["fifo", "multiqueue", "partitioned", "priority", "approx-priority"] {
        let sched = by_name(name, n, 4).unwrap();
        let r = bench(&format!("sched/{name}/add+pop x10k"), 3, 30, || {
            for v in 0..10_000u32 {
                sched.add_task(Task::with_priority(v, (v % 97) as f64));
            }
            // cycle worker ids: worker-affine schedulers (partitioned) only
            // serve their own partition
            let mut popped = 0;
            let mut idle = 0;
            let mut w = 0usize;
            while idle < 4 {
                if sched.next_task(w).is_some() {
                    popped += 1;
                    idle = 0;
                } else {
                    idle += 1;
                    w = (w + 1) % 4;
                }
            }
            assert_eq!(popped, 10_000);
        });
        push(r);
    }

    // ---- scope locking ------------------------------------------------------
    for degree in [4usize, 16] {
        let g = ring(4096, degree);
        let locks = LockTable::new(4096);
        for model in
            [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full]
        {
            let r = bench(
                &format!("scope/{}/deg{degree} x4096", model.name()),
                3,
                30,
                || {
                    for v in 0..4096u32 {
                        let s = Scope::lock(&g, &locks, v, model);
                        std::hint::black_box(s.center());
                    }
                },
            );
            push(r);
        }
    }

    // ---- engine per-update overhead ----------------------------------------
    struct Noop;
    impl UpdateFn<u64, ()> for Noop {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, _ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
        }
    }
    for workers in [1usize, 2, 4] {
        let g = ring(65_536, 4);
        let locks = LockTable::new(65_536);
        let sdt = Sdt::new();
        let noop = Noop;
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&noop];
        let sched = MultiQueueFifo::new(65_536, workers);
        let timer = Timer::start();
        for v in 0..65_536u32 {
            sched.add_task(Task::new(v));
        }
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(workers).with_model(ConsistencyModel::Edge),
        );
        let per_task = timer.elapsed_secs() / report.updates as f64;
        println!(
            "{:<44} {:>12} (engine trivial-update cost, {} workers)",
            format!("engine/noop/{workers}w"),
            fmt_secs(per_task),
            workers
        );
    }

    // throughput with a single queue for contrast
    {
        let g = ring(65_536, 4);
        let locks = LockTable::new(65_536);
        let sdt = Sdt::new();
        let noop = Noop;
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&noop];
        let sched = FifoScheduler::new(65_536);
        for v in 0..65_536u32 {
            sched.add_task(Task::new(v));
        }
        let timer = Timer::start();
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(2).with_model(ConsistencyModel::Edge),
        );
        println!(
            "{:<44} {:>12} (strict single-queue, 2 workers)",
            "engine/noop/fifo-2w",
            fmt_secs(timer.elapsed_secs() / report.updates as f64)
        );
        // priority scheduler contrast
        let sched = PriorityScheduler::new(65_536);
        for v in 0..65_536u32 {
            sched.add_task(Task::with_priority(v, (v % 13) as f64));
        }
        let timer = Timer::start();
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(2).with_model(ConsistencyModel::Edge),
        );
        println!(
            "{:<44} {:>12} (strict priority heap, 2 workers)",
            "engine/noop/priority-2w",
            fmt_secs(timer.elapsed_secs() / report.updates as f64)
        );
    }

    // ---- PJRT dispatch ------------------------------------------------------
    let dir = graphlab::runtime::default_artifact_dir();
    if dir.join("manifest.tsv").exists() {
        let mut reg = graphlab::runtime::ArtifactRegistry::open(&dir).unwrap();
        for name in ["bp_batch_b256_k5", "bp_batch_b1024_k5", "gabp_batch_b4096"] {
            let exe = reg.load(name).unwrap();
            let inputs: Vec<Vec<f32>> =
                exe.meta.inputs.iter().map(|s| vec![0.5f32; s.elements()]).collect();
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let r = bench(&format!("pjrt/{name}"), 3, 50, || {
                exe.run_f32(&refs).unwrap();
            });
            push(r);
        }
    } else {
        println!("(skipping PJRT rows: run `make artifacts`)");
    }

    // TSV dump
    std::fs::create_dir_all("results").unwrap();
    let mut f = std::fs::File::create("results/micro.tsv").unwrap();
    writeln!(f, "benchmark\tmean_s\tstddev_s\tp50_s\tp95_s").unwrap();
    for r in &rows {
        writeln!(
            f,
            "{}\t{}\t{}\t{}\t{}",
            r.name, r.summary.mean, r.summary.stddev, r.summary.p50, r.summary.p95
        )
        .unwrap();
    }
    println!("wrote results/micro.tsv");
}
