//! **Figure 7 — Shooting-algorithm Lasso** (paper §4.4).
//!
//! (a) Speedup on the *sparser* dataset under vertex vs full consistency.
//! (b) Same on the *denser* dataset — full consistency contends harder
//!     (paper: ~4x vs ~2x at 16 cpus; vertex consistency much better).
//! Plus the §4.4 text result: the relaxed run's loss lands within a
//! fraction of a percent of the sequentially-consistent one.
//!
//! Output: tables on stdout + results/fig7.tsv.

use graphlab::apps::lasso::{LassoProblem, ShootingUpdate};
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::finance::{self, FinanceConfig};
use graphlab::engine::Program;
use graphlab::metrics::{Figure, Series};
use graphlab::scheduler::{FifoScheduler, Scheduler, Task};
use graphlab::sdt::Sdt;
use graphlab::sim::{self, SimConfig};
use graphlab::util::Pcg32;
use std::path::Path;

const PROCS: &[usize] = &[1, 2, 4, 8, 16];
const LAMBDA: f32 = 2.0;
const SEED: u64 = 71;

fn capture(p: &mut LassoProblem) -> (graphlab::engine::trace::TaskTrace, Vec<Task>) {
    let n = p.graph.num_vertices();
    let sched = FifoScheduler::new(n);
    let initial: Vec<Task> = (0..p.num_weights as u32).map(Task::new).collect();
    for t in &initial {
        sched.add_task(*t);
    }
    let sdt = Sdt::new();
    let upd = ShootingUpdate::new(LAMBDA);
    let (_, trace) = Program::new()
        .update_fn(&upd)
        .model(ConsistencyModel::Full)
        .max_updates(1_200_000)
        .run_traced(&mut p.graph, &sched, &sdt);
    (trace, initial)
}

fn series_for(cfg: &FinanceConfig, label: &str, fig: &mut Figure) {
    let mut rng = Pcg32::seed_from_u64(SEED);
    let (mut p, _) = finance::generate(cfg, &mut rng);
    println!(
        "  {label}: {} features x {} docs, {} nnz",
        p.num_weights,
        p.num_obs,
        p.graph.num_edges() / 2
    );
    let (trace, initial) = capture(&mut p);
    let n = p.graph.num_vertices();
    for model in [ConsistencyModel::Full, ConsistencyModel::Vertex] {
        let cfg_sim = SimConfig {
            model,
            sched_overhead_ns: 120.0,
            sched_serialized: false,
            ..Default::default()
        };
        let results = sim::sweep_processors(&trace, &initial, n, &p.graph, &cfg_sim, PROCS);
        let curve = sim::speedups(&results);
        println!(
            "    {} consistency: {} updates, speedup@16 = {:.2}",
            model.name(),
            trace.len(),
            curve.last().unwrap().1
        );
        fig.add(Series::from_points(
            &format!("{label}-{}", model.name()),
            curve.iter().map(|&(p, s)| (p as f64, s)),
        ));
    }
}

fn threaded_loss(cfg: &FinanceConfig, model: ConsistencyModel) -> f64 {
    let mut rng = Pcg32::seed_from_u64(SEED);
    let (mut p, _) = finance::generate(cfg, &mut rng);
    let n = p.graph.num_vertices();
    let sched = FifoScheduler::new(n);
    for v in 0..p.num_weights as u32 {
        sched.add_task(Task::new(v));
    }
    let sdt = Sdt::new();
    let upd = ShootingUpdate::new(LAMBDA);
    Program::new()
        .update_fn(&upd)
        .workers(4)
        .model(model)
        .max_updates(5_000_000)
        .run(&mut p.graph, &sched, &sdt);
    p.loss(LAMBDA)
}

fn main() {
    println!("=== Fig 7: Lasso shooting, full vs vertex consistency ===");
    let sparser = FinanceConfig::sparser(0.15);
    let denser = FinanceConfig::denser(0.15);

    let mut fig = Figure::new("fig7", "shooting speedup by dataset and model", "procs", "speedup");
    series_for(&sparser, "sparser", &mut fig);
    series_for(&denser, "denser", &mut fig);
    print!("{}", fig.render());

    // §4.4: relaxed-consistency solution quality (real threaded runs).
    let loss_full = threaded_loss(&denser, ConsistencyModel::Full);
    let loss_vertex = threaded_loss(&denser, ConsistencyModel::Vertex);
    let rel = (loss_vertex - loss_full) / loss_full.max(1e-12) * 100.0;
    println!(
        "denser dataset loss: full {loss_full:.4} vs vertex {loss_vertex:.4} ({rel:+.2}%; paper: ~+0.5%)"
    );

    let p = fig.write_tsv(Path::new("results")).expect("write tsv");
    println!("wrote {}", p.display());
}
