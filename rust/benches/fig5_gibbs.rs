//! **Figure 5 — MRF Inference on the protein-interaction network** (§4.2).
//!
//! (a) Gibbs speedup: planned set schedule vs round-robin vs unplanned
//!     (barrier) set schedule — paper: plan ~10x/16, barrier suffers.
//! (b) Vertex distribution over colors (strongly skewed — the cause of the
//!     sequential component).
//! (c) Samples/sec/processor vs processors (plan vs no plan).
//! (d) Loopy BP speedup: Splash vs priority (paper: splash ~15x/16).
//! (e) Engine efficiency vs processors.
//!
//! Output: tables on stdout + results/fig5{a,b,c,d,e}.tsv.

use graphlab::apps::bp::{BpUpdate, LAMBDA_KEY};
use graphlab::apps::coloring::{color_classes, validate_coloring, ColoringUpdate};
use graphlab::apps::gibbs::{chromatic_sets, GibbsUpdate};
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::protein;
use graphlab::engine::Program;
use graphlab::metrics::{Figure, Series};
use graphlab::scheduler::set_scheduler::ExecutionPlan;
use graphlab::scheduler::{
    FifoScheduler, PriorityScheduler, RoundRobinScheduler, Scheduler, SplashScheduler, Task,
};
use graphlab::sdt::Sdt;
use graphlab::sim::{self, SimConfig, SimResult};
use graphlab::util::Pcg32;
use std::path::Path;
use std::sync::Arc;

const PROCS: &[usize] = &[1, 2, 4, 8, 16];
const N: usize = 2800; // scaled protein network (paper: 14K)
const M: usize = 20000; // undirected edges (paper: ~100K)
const SWEEPS: usize = 6;

fn main() {
    println!("=== Fig 5: protein-network MRF inference ===");
    let mut rng = Pcg32::seed_from_u64(5);
    let net = protein::generate(N, M, 3, &mut rng);
    let g = net.graph;
    let n = g.num_vertices();
    println!("MRF: {} vertices, {} directed edges", n, g.num_edges());

    // ---- coloring phase (GraphLab program, threaded) --------------------
    let mut g = g;
    {
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = ColoringUpdate;
        Program::new().update_fn(&upd).run(&mut g, &sched, &sdt);
    }
    let ncolors = validate_coloring(&mut g).expect("coloring");
    let classes = color_classes(&mut g);

    // ---- Fig 5b: color histogram ----------------------------------------
    let mut fig_b = Figure::new("fig5b", "vertex distribution over colors", "color", "vertices");
    let mut hist = Series::new("vertices");
    for (c, class) in classes.iter().enumerate() {
        hist.push(c as f64, class.len() as f64);
    }
    fig_b.add(hist);
    println!("coloring: {ncolors} colors; sizes skew from {} down to {}",
        classes.iter().map(|c| c.len()).max().unwrap(),
        classes.iter().filter(|c| !c.is_empty()).map(|c| c.len()).min().unwrap());
    print!("{}", fig_b.render());

    // ---- measure per-vertex Gibbs update costs (sequential, 1 sweep) ----
    let upd = GibbsUpdate::new(3, Arc::new(net.tables.clone()), 1, 77);
    let cost_of: Vec<f64> = {
        let sched = RoundRobinScheduler::new(n, 1);
        let sdt = Sdt::new();
        let (_, trace) = Program::new()
            .update_fn(&upd)
            .model(ConsistencyModel::Edge)
            .run_traced(&mut g, &sched, &sdt);
        let mut cost = vec![300.0f64; n];
        for e in &trace.events {
            cost[e.vertex as usize] = e.cost_ns.max(60) as f64;
        }
        cost
    };

    // ---- Fig 5a/c: chromatic Gibbs, planned vs barrier vs round-robin ---
    let sets = chromatic_sets(&classes, SWEEPS, 0);
    let plan = ExecutionPlan::compile(&sets, n, |v| g.neighbors(v), ConsistencyModel::Edge);
    println!(
        "plan: {} tasks, {} dep edges, critical path {}",
        plan.len(),
        plan.num_edges,
        plan.critical_path_len()
    );
    let base = SimConfig {
        model: ConsistencyModel::Vertex, // chromatic schedule: vertex locking
        sched_overhead_ns: 120.0,
        sched_serialized: false,
        ..Default::default()
    };
    let planned: Vec<SimResult> = PROCS
        .iter()
        .map(|&p| {
            sim::simulate_plan(&plan, n, &g, &|i| cost_of[plan.tasks[i as usize].0 as usize], false, &base.clone().with_processors(p))
        })
        .collect();
    let barrier: Vec<SimResult> = PROCS
        .iter()
        .map(|&p| {
            sim::simulate_plan(&plan, n, &g, &|i| cost_of[plan.tasks[i as usize].0 as usize], true, &base.clone().with_processors(p))
        })
        .collect();
    // round-robin trace: relies on edge consistency (paper Fig 5a)
    let rr_trace = {
        let sched = RoundRobinScheduler::new(n, SWEEPS);
        let sdt = Sdt::new();
        let (_, trace) = Program::new()
            .update_fn(&upd)
            .model(ConsistencyModel::Edge)
            .run_traced(&mut g, &sched, &sdt);
        trace
    };
    let initial: Vec<Task> = (0..n as u32).map(Task::new).collect();
    let rr_cfg = SimConfig {
        model: ConsistencyModel::Edge,
        sched_overhead_ns: 100.0,
        sched_serialized: false,
        ..Default::default()
    };
    let rr: Vec<SimResult> = sim::sweep_processors(&rr_trace, &initial, n, &g, &rr_cfg, PROCS);

    let mut fig_a = Figure::new("fig5a", "Gibbs speedup by schedule", "procs", "speedup");
    for (label, results) in
        [("planned-set", &planned), ("round-robin", &rr), ("barrier-set", &barrier)]
    {
        let curve = sim::speedups(results);
        println!("  gibbs {label}: speedup@16 = {:.2}", curve.last().unwrap().1);
        fig_a.add(Series::from_points(label, curve.iter().map(|&(p, s)| (p as f64, s))));
    }
    print!("{}", fig_a.render());

    let mut fig_c =
        Figure::new("fig5c", "samples/sec/processor", "procs", "samples_per_sec_per_proc");
    for (label, results) in [("planned-set", &planned), ("barrier-set", &barrier)] {
        fig_c.add(Series::from_points(
            label,
            results.iter().map(|r| (r.processors as f64, r.rate_per_proc())),
        ));
    }
    print!("{}", fig_c.render());

    // ---- Fig 5d: Loopy BP speedup, splash vs priority -------------------
    let mut fig_d = Figure::new("fig5d", "Loopy BP speedup", "procs", "speedup");
    let mut fig_e = Figure::new("fig5e", "engine efficiency", "procs", "efficiency");
    let mut bp_eff: Vec<(String, Vec<SimResult>)> = Vec::new();
    for (label, serialized, overhead) in
        [("splash", false, 90.0f64), ("priority", true, 250.0)]
    {
        // fresh BP-typed MRF with the same structural profile per run
        let mut rng2 = Pcg32::seed_from_u64(5);
        let mut bp_mrf = graphlab::apps::mrf::random_mrf(N, M, 3, &mut rng2);
        let bp_tables_run = Arc::new(bp_mrf.tables.clone());
        let bp_graph = &mut bp_mrf.graph;
        let nb = bp_graph.num_vertices();
        let sdt = Sdt::new();
        sdt.set(LAMBDA_KEY, [1.0f64; 3]);
        let bp = BpUpdate::new(3, 1e-3, bp_tables_run);
        let program = Program::new()
            .update_fn(&bp)
            .model(ConsistencyModel::Edge)
            .max_updates(400_000);
        let trace = {
            let initial: Vec<Task> =
                (0..nb as u32).map(|v| Task::with_priority(v, 1.0)).collect();
            let mut run = |sched: &dyn Scheduler| {
                for t in &initial {
                    sched.add_task(*t);
                }
                program.run_traced(bp_graph, sched, &sdt).1
            };
            match label {
                "splash" => {
                    let adj: Vec<Vec<u32>> =
                        (0..nb as u32).map(|v| g.neighbors(v).to_vec()).collect();
                    run(&SplashScheduler::new(nb, |v| adj[v as usize].as_slice(), 48, 16))
                }
                _ => run(&PriorityScheduler::new(nb)),
            }
        };
        let cfg = SimConfig {
            model: ConsistencyModel::Edge,
            sched_overhead_ns: overhead,
            sched_serialized: serialized,
            ..Default::default()
        };
        let initial: Vec<Task> = (0..nb as u32).map(|v| Task::with_priority(v, 1.0)).collect();
        let results = sim::sweep_processors(&trace, &initial, nb, &g, &cfg, PROCS);
        let curve = sim::speedups(&results);
        println!("  bp {label}: {} updates, speedup@16 = {:.2}", trace.len(), curve.last().unwrap().1);
        fig_d.add(Series::from_points(label, curve.iter().map(|&(p, s)| (p as f64, s))));
        bp_eff.push((label.to_string(), results));
    }
    print!("{}", fig_d.render());

    // ---- Fig 5e: efficiency -----------------------------------------------
    fig_e.add(Series::from_points(
        "gibbs-planned",
        planned.iter().map(|r| (r.processors as f64, r.efficiency())),
    ));
    for (label, results) in &bp_eff {
        fig_e.add(Series::from_points(
            &format!("bp-{label}"),
            results.iter().map(|r| (r.processors as f64, r.efficiency())),
        ));
    }
    print!("{}", fig_e.render());

    let out = Path::new("results");
    for f in [&fig_a, &fig_b, &fig_c, &fig_d, &fig_e] {
        let p = f.write_tsv(out).expect("write tsv");
        println!("wrote {}", p.display());
    }
}
