//! **Figure 8 — Compressed sensing** (paper §4.5).
//!
//! (a) Speedup of the interior-point algorithm: the sequential Newton outer
//!     loop drives GaBP inner solves; the inner solves are the parallel
//!     part (paper: ~8x at 16 cpus with round-robin scheduling). Measured
//!     by capturing the GaBP trace of each Newton iteration and replaying
//!     all of them on P simulated processors (the outer loop stays serial —
//!     exactly the paper's Amdahl structure).
//!
//! (b/c) The image outputs are produced by `examples/compressed_sensing.rs`.
//!
//! Output: table on stdout + results/fig8a.tsv.

use graphlab::apps::cs::{sparse_measurements, CsProblem, CsSolver};
use graphlab::apps::gabp::GabpUpdate;
use graphlab::apps::wavelet::{haar2d, sparsify};
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::image;
use graphlab::engine::Program;
use graphlab::metrics::{Figure, Series};
use graphlab::scheduler::{RoundRobinScheduler, Task};
use graphlab::sdt::Sdt;
use graphlab::sim::{self, SimConfig};
use graphlab::util::Pcg32;
use std::path::Path;

const PROCS: &[usize] = &[1, 2, 4, 8, 16];
const OUTER: usize = 10;

fn main() {
    println!("=== Fig 8: compressed sensing interior point ===");
    let size = 32usize;
    let n = size * size;
    let mut rng = Pcg32::seed_from_u64(12);
    let original = image::generate(size, &mut rng);
    let mut coeffs = original;
    haar2d(&mut coeffs, size);
    sparsify(&mut coeffs, n / 12);
    let w_true: Vec<f64> = coeffs.iter().map(|&c| c as f64).collect();
    let m = (0.55 * n as f64) as usize;
    let rows = sparse_measurements(n, m, 6, &mut rng);
    let clean = CsProblem { n, rows: rows.clone(), y: vec![], lambda: 0.0, rho: 0.0, eps: 1.0 };
    let y = clean.forward(&w_true);
    let problem = CsProblem { n, rows, y, lambda: 0.02, rho: 1e-4, eps: 1e-6 };
    println!("{n} coefficients, {m} measurements");

    let mut solver = CsSolver::new(problem);
    let upd = GabpUpdate::new(1e-9);
    // accumulated makespans per processor count across Newton iterations
    let mut totals = vec![0.0f64; PROCS.len()];
    let mut serial_ns = 0.0f64; // outer-loop work, charged at 1x
    for outer in 0..OUTER {
        let t_outer = graphlab::util::Timer::start();
        solver.prepare_newton();
        serial_ns += t_outer.elapsed_ns() as f64;
        let sched = RoundRobinScheduler::new(n, 40);
        let sdt = Sdt::new();
        let (_, trace) = Program::new()
            .update_fn(&upd)
            .model(ConsistencyModel::Edge)
            .run_traced(&mut solver.graph, &sched, &sdt);
        let initial: Vec<Task> = (0..n as u32).map(Task::new).collect();
        let cfg = SimConfig {
            model: ConsistencyModel::Edge,
            sched_overhead_ns: 100.0,
            sched_serialized: false,
            ..Default::default()
        };
        let results = sim::sweep_processors(&trace, &initial, n, &solver.graph, &cfg, PROCS);
        for (t, r) in totals.iter_mut().zip(&results) {
            *t += r.makespan_ns;
        }
        let t_outer = graphlab::util::Timer::start();
        let alpha = solver.apply_direction();
        let gap = solver.problem.duality_gap(&solver.w);
        serial_ns += t_outer.elapsed_ns() as f64;
        if outer % 3 == 0 {
            println!("  newton iter {outer}: {} gabp updates, step {alpha:.3}, gap {gap:.3e}", trace.len());
        }
    }

    let mut fig = Figure::new("fig8a", "interior point speedup", "procs", "speedup");
    let base = totals[0] + serial_ns;
    let mut series = Series::new("round-robin-gabp");
    for (i, &p) in PROCS.iter().enumerate() {
        let s = base / (totals[i] + serial_ns);
        println!("  P={p}: speedup {s:.2}");
        series.push(p as f64, s);
    }
    fig.add(series);
    print!("{}", fig.render());
    let p = fig.write_tsv(Path::new("results")).expect("write tsv");
    println!("wrote {}", p.display());
}
