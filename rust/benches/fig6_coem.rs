//! **Figure 6 + §4.3 — CoEM named-entity recognition** (paper §4.3).
//!
//! * dataset table (the §4.3 table, for the generated stand-ins);
//! * (a, b) speedup of MultiQueue FIFO vs Partitioned on both datasets
//!   (paper: similar, near-linear; large scales better);
//! * (c) convergence (updates to reach a quality level) for dynamic
//!   (MultiQueue FIFO) vs Round-robin scheduling;
//! * (d) speedup at 16 cpus vs graph size (subsampled);
//! * the Hadoop comparison (data persistence vs per-iteration copying).
//!
//! Output: tables on stdout + results/fig6{ab,c,d}.tsv.

use graphlab::apps::coem::{belief_distance, CoemUpdate, CoemVertex};
use graphlab::apps::coem::CoemEdge;
use graphlab::baselines::mapreduce::{compare, MapReduceCosts};
use graphlab::baselines::sequential::coem_jacobi;
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::ner::{self, NerConfig};
use graphlab::engine::Program;
use graphlab::graph::{induced_subgraph, DataGraph};
use graphlab::metrics::{Figure, Series};
use graphlab::scheduler::{MultiQueueFifo, PartitionedScheduler, RoundRobinScheduler, Scheduler, Task};
use graphlab::sdt::Sdt;
use graphlab::sim::{self, SimConfig};
use graphlab::util::Pcg32;
use std::path::Path;

const PROCS: &[usize] = &[1, 2, 4, 8, 16];

fn capture_trace(
    graph: &mut DataGraph<CoemVertex, CoemEdge>,
    classes: usize,
    scheduler: &dyn Scheduler,
) -> graphlab::engine::trace::TaskTrace {
    let n = graph.num_vertices();
    for v in 0..n as u32 {
        scheduler.add_task(Task::new(v));
    }
    let sdt = Sdt::new();
    let mut upd = CoemUpdate::new(classes);
    upd.threshold = 1e-4; // bench-scale convergence
    let (_, trace) = Program::new()
        .update_fn(&upd)
        .model(ConsistencyModel::Vertex)
        .max_updates(350_000)
        .virtual_workers(16)
        .run_traced(graph, scheduler, &sdt);
    trace
}

fn speedup_figure(
    label_prefix: &str,
    cfg: &NerConfig,
    seed: u64,
    fig: &mut Figure,
) -> f64 {
    let initial: Vec<Task> = {
        let mut rng = Pcg32::seed_from_u64(seed);
        let g = ner::generate(cfg, &mut rng);
        (0..g.num_vertices() as u32).map(Task::new).collect()
    };
    let mut speedup16 = 0.0f64;
    for (sched_name, overhead) in [("multiqueue", 130.0f64), ("partitioned", 90.0)] {
        let mut rng = Pcg32::seed_from_u64(seed);
        let mut g = ner::generate(cfg, &mut rng);
        let n = g.num_vertices();
        let trace = match sched_name {
            "multiqueue" => capture_trace(&mut g, cfg.classes, &MultiQueueFifo::new(n, 16)),
            _ => capture_trace(&mut g, cfg.classes, &PartitionedScheduler::new(n, 16)),
        };
        let sim_cfg = SimConfig {
            model: ConsistencyModel::Vertex,
            sched_overhead_ns: overhead,
            sched_serialized: false,
            // multiqueue shares 2P queue heads; partitioned queues are
            // worker-private (paper §3.4's locality argument)
            contention_factor: if sched_name == "multiqueue" { 0.09 } else { 0.06 },
            ..Default::default()
        };
        let results = sim::sweep_processors(&trace, &initial, n, &g, &sim_cfg, PROCS);
        let curve = sim::speedups(&results);
        println!(
            "  {label_prefix}/{sched_name}: {} updates, speedup@16 = {:.2}",
            trace.len(),
            curve.last().unwrap().1
        );
        speedup16 = speedup16.max(curve.last().unwrap().1);
        fig.add(Series::from_points(
            &format!("{label_prefix}-{sched_name}"),
            curve.iter().map(|&(p, s)| (p as f64, s)),
        ));
    }
    speedup16
}

fn main() {
    println!("=== Fig 6 / §4.3: CoEM ===");
    let small = NerConfig::small(0.06);
    let large = NerConfig::large(0.018);

    // §4.3 dataset table
    println!("{:<7} {:>8} {:>9} {:>10} {:>8}", "name", "classes", "verts", "edges", "seeds%");
    for (name, cfg) in [("small", &small), ("large", &large)] {
        println!(
            "{:<7} {:>8} {:>9} {:>10} {:>8.1}",
            name,
            cfg.classes,
            cfg.num_np + cfg.num_ct,
            cfg.num_edges,
            cfg.seed_fraction * 100.0
        );
    }

    // ---- Fig 6a/b --------------------------------------------------------
    let mut fig_ab =
        Figure::new("fig6ab", "CoEM speedup by scheduler and dataset", "procs", "speedup");
    speedup_figure("small", &small, 61, &mut fig_ab);
    speedup_figure("large", &large, 62, &mut fig_ab);
    print!("{}", fig_ab.render());

    // ---- Fig 6c: updates-to-quality, dynamic vs round-robin --------------
    let mut fig_c = Figure::new(
        "fig6c",
        "updates to reach quality (L1 distance to fixed point)",
        "updates_per_vertex",
        "l1_distance",
    );
    {
        // well-mixed instance so both stopping rules actually converge
        let mut cfg_c = small.clone();
        cfg_c.seed_fraction = 0.25;
        let mk = || {
            let mut rng = Pcg32::seed_from_u64(63);
            ner::generate(&cfg_c, &mut rng)
        };
        // empirical fixed point from a long synchronous run
        let mut gstar = mk();
        let reference = coem_jacobi(&mut gstar, small.classes, 400, 0.5);
        let n = gstar.num_vertices();

        let mut dyn_series = Series::new("multiqueue-dynamic");
        let mut rr_series = Series::new("round-robin");
        for budget_per_vertex in [1usize, 2, 4, 8, 16] {
            let budget = (budget_per_vertex * n) as u64;
            // dynamic
            let mut g = mk();
            let sched = MultiQueueFifo::new(n, 16);
            for v in 0..n as u32 {
                sched.add_task(Task::new(v));
            }
            let sdt = Sdt::new();
            let mut upd = CoemUpdate::new(small.classes);
            upd.threshold = 1e-3; // only meaningful moves reschedule
            Program::new()
                .update_fn(&upd)
                .model(ConsistencyModel::Vertex)
                .max_updates(budget)
                .workers(1) // deterministic sequential back-end
                .virtual_workers(16)
                .run(&mut g, &sched, &sdt);
            dyn_series.push(budget_per_vertex as f64, belief_distance(&mut g, &reference));
            // round-robin
            let mut g = mk();
            let sched = RoundRobinScheduler::new(n, budget_per_vertex);
            Program::new()
                .update_fn(&upd)
                .model(ConsistencyModel::Vertex)
                .max_updates(budget)
                .workers(1) // deterministic sequential back-end
                .run(&mut g, &sched, &sdt);
            rr_series.push(budget_per_vertex as f64, belief_distance(&mut g, &reference));
        }
        fig_c.add(dyn_series);
        fig_c.add(rr_series);
    }
    print!("{}", fig_c.render());

    // ---- Fig 6d: speedup@16 vs graph size --------------------------------
    let mut fig_d = Figure::new("fig6d", "speedup at 16 cpus vs graph size", "fraction", "speedup16");
    {
        let mut series = Series::new("multiqueue");
        for fraction in [0.33f64, 0.66, 1.0] {
            let mut rng = Pcg32::seed_from_u64(64);
            let mut full = ner::generate(&large, &mut rng);
            let (mut sub, _) = induced_subgraph(&mut full, fraction, &mut rng);
            let n = sub.num_vertices();
            let trace = capture_trace(&mut sub, large.classes, &MultiQueueFifo::new(n, 16));
            let initial: Vec<Task> = (0..n as u32).map(Task::new).collect();
            let sim_cfg = SimConfig {
                model: ConsistencyModel::Vertex,
                sched_overhead_ns: 130.0,
                contention_factor: 0.09,
                ..Default::default()
            };
            let results =
                sim::sweep_processors(&trace, &initial, n, &sub, &sim_cfg, &[1, 16]);
            let s16 = results[0].makespan_ns / results[1].makespan_ns;
            println!("  fraction {fraction}: n={n}, speedup@16 = {s16:.2}");
            series.push(fraction, s16);
        }
        fig_d.add(series);
    }
    print!("{}", fig_d.render());

    // ---- Hadoop comparison (§4.3 text) ------------------------------------
    {
        let mut rng = Pcg32::seed_from_u64(65);
        let mut g = ner::generate(&small, &mut rng);
        let cmp = compare(&mut g, small.classes, 3, &MapReduceCosts::default());
        println!(
            "Hadoop-model comparison (3 sweeps, this graph): GraphLab compute {:.3}s; \
             MapReduce charges {:.1}s data-motion per iteration ({:.0}s total on 95 nodes).",
            cmp.graphlab_s, cmp.per_iteration_io_s, cmp.mapreduce_s
        );
        println!(
            "  -> per-iteration data motion dominates compute by {:.0}x at this scale; the paper \
             measured 15x wall-clock (30 min on 16 cores vs 7.5 h on ~95) — same mechanism, \
             data persistence vs per-iteration materialization."  ,
            cmp.ratio() / cmp.iterations as f64
        );
    }

    let out = Path::new("results");
    for f in [&fig_ab, &fig_c, &fig_d] {
        let p = f.write_tsv(out).expect("write tsv");
        println!("wrote {}", p.display());
    }
}
