//! **Figure 4 — Retinal Scan Denoising** (paper §4.1).
//!
//! (a) Speedup of parameter learning under the priority, approx-priority and
//!     Splash schedules (paper: Splash wins, ~15x on 16 procs). Measured by
//!     capturing a sequential task trace per scheduler and replaying it on
//!     the multicore simulator (DESIGN.md §Testbed-substitutions).
//! (b) Total runtime vs the background gradient-step interval.
//! (c) Average % deviation of the learned parameters vs the interval.
//!
//! Output: tables on stdout + results/fig4{a,bc}.tsv.

use graphlab::apps::bp::{BpUpdate, LAMBDA_KEY};
use graphlab::apps::learn::{learning_sync, target_stats, TARGET_KEY};
use graphlab::apps::mrf::GridDims;
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::retina;
use graphlab::engine::Program;
use graphlab::metrics::{Figure, Series};
use graphlab::scheduler::{
    ApproxPriorityScheduler, PriorityScheduler, Scheduler, SplashScheduler, Task,
};
use graphlab::sdt::Sdt;
use graphlab::sim::{self, SimConfig};
use graphlab::util::{Pcg32, Timer};
use std::path::Path;
use std::sync::Arc;

const PROCS: &[usize] = &[1, 2, 4, 8, 16];
const MAX_UPDATES: u64 = 600_000;

fn make_workload() -> (retina::RetinaVolume, [f64; 3]) {
    let mut rng = Pcg32::seed_from_u64(42);
    let dims = GridDims::new(20, 20, 10);
    let vol = retina::generate(dims, 5, 0.25, &mut rng);
    let proxy = retina::smoothed_proxy(&vol, 1);
    let targets = target_stats(dims, &proxy);
    (vol, targets)
}

/// Capture a sequential learning trace under the given scheduler.
fn capture(
    vol: &retina::RetinaVolume,
    targets: [f64; 3],
    scheduler: &dyn Scheduler,
    initial: &[Task],
) -> (graphlab::engine::trace::TaskTrace, usize) {
    let mut mrf = retina::build_mrf(vol, 0.8);
    let n = mrf.graph.num_vertices();
    let sdt = Sdt::new();
    sdt.set(LAMBDA_KEY, [1.0f64; 3]);
    sdt.set(TARGET_KEY, targets);
    let mut upd = BpUpdate::new(5, 5e-4, Arc::new(Vec::new()));
    upd.learn_stats = true;
    upd.damping = 0.1;
    let sync = learning_sync(0.25, None);
    for t in initial {
        scheduler.add_task(*t);
    }
    let (_, trace) = Program::new()
        .update_fn(&upd)
        .sync(sync)
        .workers(1)
        .model(ConsistencyModel::Edge)
        .max_updates(MAX_UPDATES)
        .sync_every(2_000)
        .run_traced(&mut mrf.graph, scheduler, &sdt);
    (trace, n)
}

fn fig4a(vol: &retina::RetinaVolume, targets: [f64; 3]) -> Figure {
    let mrf = {
        let m = retina::build_mrf(vol, 0.8);
        m
    };
    let n = mrf.graph.num_vertices();
    let initial: Vec<Task> = (0..n as u32).map(|v| Task::with_priority(v, 1.0)).collect();

    let mut fig = Figure::new("fig4a", "param-learning speedup by scheduler", "procs", "speedup");
    // (scheduler name, strict/serialized dispatch?, per-pop overhead ns)
    let schedulers: Vec<(&str, bool, f64)> =
        vec![("priority", true, 250.0), ("approx-priority", false, 150.0), ("splash", false, 90.0)];
    for (name, serialized, overhead) in schedulers {
        let timer = Timer::start();
        let (trace, _) = match name {
            "priority" => capture(vol, targets, &PriorityScheduler::new(n), &initial),
            "approx-priority" => {
                capture(vol, targets, &ApproxPriorityScheduler::new(n, 16), &initial)
            }
            "splash" => capture(
                vol,
                targets,
                &SplashScheduler::new(n, |v| mrf.graph.neighbors(v), 48, 16),
                &initial,
            ),
            _ => unreachable!(),
        };
        let cfg = SimConfig {
            model: ConsistencyModel::Edge,
            sched_overhead_ns: overhead,
            sched_serialized: serialized,
            ..Default::default()
        };
        let results = sim::sweep_processors(&trace, &initial, n, &mrf.graph, &cfg, PROCS);
        let curve = sim::speedups(&results);
        println!(
            "  {name}: {} updates traced in {:.1}s, speedup@16 = {:.2}",
            trace.len(),
            timer.elapsed_secs(),
            curve.last().unwrap().1
        );
        fig.add(Series::from_points(
            name,
            curve.iter().map(|&(p, s)| (p as f64, s)),
        ));
    }
    fig
}

/// Fig 4b/c: real threaded runs sweeping the background sync interval.
fn fig4bc(vol: &retina::RetinaVolume, targets: [f64; 3]) -> (Figure, Figure) {
    // Reference lambda* from a tight-interval run.
    let reference = run_learning(vol, targets, 1);
    let mut fig_b = Figure::new("fig4b", "runtime vs gradient-step interval", "interval_ms", "seconds");
    let mut fig_c =
        Figure::new("fig4c", "param deviation vs gradient-step interval", "interval_ms", "percent");
    let mut runtime = Series::new("runtime");
    let mut deviation = Series::new("deviation");
    for interval_ms in [1u64, 2, 5, 10, 25, 50] {
        let timer = Timer::start();
        let lambda = run_learning(vol, targets, interval_ms);
        let secs = timer.elapsed_secs();
        let dev = (0..3)
            .map(|a| ((lambda[a] - reference[a]) / reference[a].max(1e-9)).abs())
            .sum::<f64>()
            / 3.0
            * 100.0;
        println!(
            "  interval {interval_ms:>3} ms: {secs:.2}s, lambda [{:.3} {:.3} {:.3}], deviation {dev:.2}%",
            lambda[0], lambda[1], lambda[2]
        );
        runtime.push(interval_ms as f64, secs);
        deviation.push(interval_ms as f64, dev);
    }
    fig_b.add(runtime);
    fig_c.add(deviation);
    (fig_b, fig_c)
}

fn run_learning(vol: &retina::RetinaVolume, targets: [f64; 3], interval_ms: u64) -> [f64; 3] {
    let mut mrf = retina::build_mrf(vol, 0.8);
    let n = mrf.graph.num_vertices();
    let sdt = Sdt::new();
    sdt.set(LAMBDA_KEY, [1.0f64; 3]);
    sdt.set(TARGET_KEY, targets);
    let sched = SplashScheduler::new(n, |v| mrf.graph.neighbors(v), 48, 2);
    for v in 0..n as u32 {
        sched.add_task(Task::with_priority(v, 1.0));
    }
    let mut upd = BpUpdate::new(5, 5e-4, Arc::new(Vec::new()));
    upd.learn_stats = true;
    upd.damping = 0.1;
    let sync = learning_sync(0.25, Some(std::time::Duration::from_millis(interval_ms)));
    Program::new()
        .update_fn(&upd)
        .sync(sync)
        .workers(2)
        .model(ConsistencyModel::Edge)
        .max_updates(MAX_UPDATES)
        .run(&mut mrf.graph, &sched, &sdt);
    sdt.get::<[f64; 3]>(LAMBDA_KEY).unwrap()
}

fn main() {
    println!("=== Fig 4: retinal-scan denoising / parameter learning ===");
    let (vol, targets) = make_workload();
    println!(
        "workload: {}x{}x{} grid, noisy error rate {:.3}",
        vol.dims.nx,
        vol.dims.ny,
        vol.dims.nz,
        retina::error_rate(&vol.clean, &vol.noisy)
    );

    let fig_a = fig4a(&vol, targets);
    print!("{}", fig_a.render());
    let (fig_b, fig_c) = fig4bc(&vol, targets);
    print!("{}", fig_b.render());
    print!("{}", fig_c.render());

    let out = Path::new("results");
    for f in [&fig_a, &fig_b, &fig_c] {
        let p = f.write_tsv(out).expect("write tsv");
        println!("wrote {}", p.display());
    }
}
