//! CoEM named-entity recognition (paper §4.3) on the synthetic web-crawl
//! stand-in: seed a few noun phrases with labels and let the belief
//! averaging propagate them over the NP–context co-occurrence graph.
//!
//! Run: `cargo run --release --example coem_ner -- [--scale 0.25]`

use graphlab::apps::coem::{CoemUpdate, CoemVertex};
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::ner;
use graphlab::engine::Program;
use graphlab::scheduler::{MultiQueueFifo, Scheduler, Task};
use graphlab::sdt::Sdt;
use graphlab::util::{Cli, Pcg32, Timer};

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("coem_ner", "CoEM semi-supervised NER on a synthetic co-occurrence graph")
        .opt("scale", "0.25", "dataset scale (1.0 = 20K vertices / 200K edges)")
        .opt("workers", "4", "worker threads")
        .opt("seed", "3", "rng seed")
        .flag("large", "use the large-shaped (multi-class) dataset");
    let args = cli.parse(&std::env::args().skip(1).collect::<Vec<_>>()).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let scale = args.get_f64("scale")?;
    let cfg = if args.get_flag("large") {
        ner::NerConfig::large(scale)
    } else {
        ner::NerConfig::small(scale)
    };
    let mut rng = Pcg32::seed_from_u64(args.get_u64("seed")?);
    let mut g = ner::generate(&cfg, &mut rng);
    let n = g.num_vertices();
    println!(
        "dataset: {} NPs + {} CTs, {} directed edges, {} classes",
        cfg.num_np,
        cfg.num_ct,
        g.num_edges(),
        cfg.classes
    );

    let workers = args.get_usize("workers")?;
    let sched = MultiQueueFifo::new(n, workers);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    let sdt = Sdt::new();
    let upd = CoemUpdate::new(cfg.classes);
    let timer = Timer::start();
    let report = Program::new()
        .update_fn(&upd)
        .workers(workers)
        .model(ConsistencyModel::Vertex)
        .max_updates(50_000_000)
        .run(&mut g, &sched, &sdt);
    let secs = timer.elapsed_secs();
    println!(
        "converged: {} updates in {:.2}s ({:.0} updates/s, {:.1} updates/vertex)",
        report.updates,
        secs,
        report.updates as f64 / secs,
        report.updates as f64 / n as f64
    );

    // Report label confidence over the unlabeled NPs.
    let mut confident = 0usize;
    let mut total_unlabeled = 0usize;
    for v in 0..cfg.num_np as u32 {
        let vd: &CoemVertex = g.vertex_data(v);
        if vd.seed {
            continue;
        }
        total_unlabeled += 1;
        let best = vd.belief.iter().cloned().fold(0.0f32, f32::max);
        if best > 0.6 {
            confident += 1;
        }
    }
    println!(
        "confident (>0.6) labels on {}/{} unlabeled NPs ({:.1}%)",
        confident,
        total_unlabeled,
        100.0 * confident as f64 / total_unlabeled.max(1) as f64
    );
    assert!(confident * 2 > total_unlabeled, "label propagation must reach most NPs");
    println!("coem_ner OK");
    Ok(())
}
