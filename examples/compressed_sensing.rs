//! Compressed sensing (paper §4.5, Fig. 8): recover a procedural test image
//! from random sparse measurements of its Haar wavelet coefficients, with
//! the interior-point outer loop driving GaBP inner solves on the GraphLab
//! engine. Writes the original and reconstruction as PGMs.
//!
//! Run: `cargo run --release --example compressed_sensing -- [--size 64]`

use graphlab::apps::cs::{sparse_measurements, CsProblem, CsSolver};
use graphlab::apps::wavelet::{haar2d, ihaar2d, sparsify};
use graphlab::datagen::image;
use graphlab::metrics::write_pgm;
use graphlab::util::stats::psnr;
use graphlab::util::{Cli, Pcg32, Timer};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("compressed_sensing", "interior-point CS reconstruction with GaBP inner solves")
        .opt("size", "64", "image side (power of two)")
        .opt("measurements", "0.55", "measurements as a fraction of pixels")
        .opt("per-row", "6", "non-zeros per measurement row")
        .opt("keep", "0.08", "wavelet sparsity of the ground truth")
        .opt("workers", "2", "engine workers for the inner solves")
        .opt("outer", "120", "max Newton iterations")
        .opt("seed", "12", "rng seed")
        .opt("out-dir", "results", "output directory");
    let args = cli.parse(&std::env::args().skip(1).collect::<Vec<_>>()).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let size = args.get_usize("size")?;
    let n = size * size;
    let mut rng = Pcg32::seed_from_u64(args.get_u64("seed")?);

    // Ground truth: procedural image, sparsified in the Haar basis
    // (the paper's "sparse linear combination of basis functions").
    let original = image::generate(size, &mut rng);
    let mut coeffs = original.clone();
    haar2d(&mut coeffs, size);
    sparsify(&mut coeffs, (n as f64 * args.get_f64("keep")?) as usize);
    let mut target_img = coeffs.clone();
    ihaar2d(&mut target_img, size);
    let w_true: Vec<f64> = coeffs.iter().map(|&c| c as f64).collect();

    // Random sparse measurement ensemble y = M w.
    let m = (n as f64 * args.get_f64("measurements")?) as usize;
    let rows = sparse_measurements(n, m, args.get_usize("per-row")?, &mut rng);
    let clean = CsProblem { n, rows: rows.clone(), y: vec![], lambda: 0.0, rho: 0.0, eps: 1.0 };
    let y = clean.forward(&w_true);
    println!(
        "image {size}x{size}: {} wavelet coefficients, {m} measurements ({} per row)",
        n,
        args.get_usize("per-row")?
    );

    // Interior point with GaBP inner solves (Alg. 5).
    let problem = CsProblem { n, rows, y, lambda: 0.02, rho: 1e-4, eps: 1e-6 };
    let mut solver = CsSolver::new(problem);
    let timer = Timer::start();
    let stats = solver.solve(args.get_usize("workers")?, args.get_usize("outer")?, 1e-3);
    println!(
        "interior point: {} outer iterations, {} GaBP updates, gap {:.2e}, {:.2}s",
        stats.outer_iterations,
        stats.inner_updates,
        stats.final_gap,
        timer.elapsed_secs()
    );
    for (i, (gap, obj)) in stats.history.iter().enumerate() {
        println!("  iter {:>2}: duality gap {gap:>10.4e}  objective {obj:.4}", i + 1);
    }

    // Reconstruct and score.
    let mut recon = solver.w.iter().map(|&w| w as f32).collect::<Vec<f32>>();
    ihaar2d(&mut recon, size);
    let rel_err = {
        let num: f64 = recon
            .iter()
            .zip(&target_img)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 =
            target_img.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().sqrt();
        num / den
    };
    let p = psnr(&target_img, &recon, 1.0);
    println!("reconstruction: relative L2 error {rel_err:.4}, PSNR {p:.2} dB");
    assert!(rel_err < 0.2, "reconstruction must be close: rel err {rel_err}");

    let out = args.get("out-dir");
    write_pgm(Path::new(out).join("fig8b_original.pgm").as_path(), &target_img, size, size)?;
    let clipped: Vec<f32> = recon.iter().map(|&x| x.clamp(0.0, 1.0)).collect();
    write_pgm(Path::new(out).join("fig8c_reconstruction.pgm").as_path(), &clipped, size, size)?;
    println!("wrote {out}/fig8b_original.pgm and {out}/fig8c_reconstruction.pgm");
    println!("compressed_sensing OK");
    Ok(())
}
