//! Chromatic parallel Gibbs sampling (paper §4.2) on the protein-network
//! stand-in: color the MRF with a GraphLab update function, compile the
//! color classes into a planned set schedule, and draw samples in parallel
//! with full sequential-consistency guarantees.
//!
//! Run: `cargo run --release --example gibbs_sampling -- [--vertices 2000]`

use graphlab::apps::coloring::{color_classes, validate_coloring, ColoringUpdate};
use graphlab::apps::gibbs::{chromatic_sets, GibbsUpdate};
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::protein;
use graphlab::engine::Program;
use graphlab::metrics::run_summary;
use graphlab::scheduler::{FifoScheduler, Scheduler, SetScheduler, Task};
use graphlab::sdt::Sdt;
use graphlab::util::{Cli, Pcg32, Timer};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("gibbs_sampling", "chromatic parallel Gibbs on a protein-like MRF")
        .opt("vertices", "2000", "MRF vertices")
        .opt("edges", "12000", "MRF undirected edges")
        .opt("arity", "3", "variable cardinality")
        .opt("sweeps", "200", "Gibbs sweeps")
        .opt("workers", "4", "worker threads")
        .opt("seed", "7", "rng seed");
    let args = cli.parse(&std::env::args().skip(1).collect::<Vec<_>>()).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let mut rng = Pcg32::seed_from_u64(args.get_u64("seed")?);
    let net = protein::generate(
        args.get_usize("vertices")?,
        args.get_usize("edges")?,
        args.get_usize("arity")?,
        &mut rng,
    );
    let mut g = net.graph;
    let n = g.num_vertices();
    println!("MRF: {} vertices, {} directed edges", n, g.num_edges());

    // Phase 1: parallel greedy coloring (edge consistency).
    let timer = Timer::start();
    {
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = ColoringUpdate;
        Program::new()
            .update_fn(&upd)
            .workers(args.get_usize("workers")?)
            .model(ConsistencyModel::Edge)
            .run(&mut g, &sched, &sdt);
    }
    let ncolors = validate_coloring(&mut g).map_err(|e| anyhow::anyhow!(e))?;
    let classes = color_classes(&mut g);
    let mut sizes: Vec<usize> = classes.iter().map(|c| c.len()).collect();
    println!("coloring: {ncolors} colors in {:.3}s", timer.elapsed_secs());
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    println!("color class sizes (sorted): {:?}", &sizes[..sizes.len().min(12)]);

    // Phase 2: planned set-schedule Gibbs (vertex locking; edge-model plan).
    let sweeps = args.get_usize("sweeps")?;
    let sets = chromatic_sets(&classes, sweeps, 0);
    let plan_timer = Timer::start();
    let sched = SetScheduler::planned(&sets, n, |v| g.neighbors(v), ConsistencyModel::Edge);
    println!(
        "execution plan: {} tasks, {} dep edges, critical path {} (compiled in {:.3}s)",
        sched.plan().len(),
        sched.plan().num_edges,
        sched.plan().critical_path_len(),
        plan_timer.elapsed_secs()
    );
    let upd = GibbsUpdate::new(
        net.arity,
        Arc::new(net.tables.clone()),
        args.get_usize("workers")?,
        args.get_u64("seed")?,
    );
    let sdt = Sdt::new();
    let timer = Timer::start();
    let report = Program::new()
        .update_fn(&upd)
        .workers(args.get_usize("workers")?)
        .model(ConsistencyModel::Vertex)
        .run(&mut g, &sched, &sdt);
    let secs = timer.elapsed_secs();
    println!(
        "sampling: {} samples in {:.2}s ({:.0} samples/s)",
        report.updates,
        secs,
        report.updates as f64 / secs
    );
    print!("{}", run_summary(&report));
    assert_eq!(report.updates as usize, n * sweeps);

    // Sanity: marginals are proper distributions and not all uniform.
    let mut max_dev = 0.0f32;
    for v in 0..n as u32 {
        let m = g.vertex_data(v).marginal();
        let sum: f32 = m.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4);
        let u = 1.0 / net.arity as f32;
        for p in &m {
            max_dev = max_dev.max((p - u).abs());
        }
    }
    println!("max marginal deviation from uniform: {max_dev:.3}");
    assert!(max_dev > 0.05, "potentials must bias the marginals");
    println!("gibbs_sampling OK");
    Ok(())
}
