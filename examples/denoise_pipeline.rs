//! **End-to-end driver** (paper §4.1, Fig. 4d/e): the complete retinal-scan
//! denoising pipeline on a real (synthetic) workload, proving all layers
//! compose:
//!
//! 1. generate a layered 3-D volume + speckle noise (`datagen::retina`);
//! 2. compute proxy ground-truth statistics with the **sync** mechanism;
//! 3. run **simultaneous parameter learning and BP inference**: the engine
//!    applies residual-scheduled BP updates while the background sync takes
//!    gradient steps on λ (Alg. 3);
//! 4. read out expectations per voxel, report error-rate / PSNR, and write
//!    noisy/denoised mid-volume slices as PGM images;
//! 5. `--accel` reruns inference through the AOT-compiled Pallas kernel via
//!    PJRT (Layer 1/2) and cross-checks the beliefs.
//!
//! Run: `cargo run --release --example denoise_pipeline -- [--accel]`

use graphlab::apps::bp::{BpUpdate, LAMBDA_KEY};
use graphlab::apps::learn::{learning_sync, target_stats, STEPS_KEY, TARGET_KEY};
use graphlab::apps::mrf::GridDims;
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::retina;
use graphlab::engine::Program;
use graphlab::metrics::{run_summary, write_pgm};
use graphlab::scheduler::{Scheduler, SplashScheduler, Task};
use graphlab::sdt::Sdt;
use graphlab::util::stats::psnr;
use graphlab::util::{Cli, Pcg32, Timer};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("denoise_pipeline", "3-D retinal denoising with learned MRF parameters")
        .opt("nx", "24", "volume x size")
        .opt("ny", "24", "volume y size")
        .opt("nz", "12", "volume z size")
        .opt("levels", "5", "intensity levels (MRF arity)")
        .opt("noise", "0.25", "speckle corruption probability")
        .opt("workers", "4", "engine worker threads")
        .opt("sync-ms", "2", "background gradient-step interval (ms)")
        .opt("seed", "42", "rng seed")
        .opt("out-dir", "results", "output directory for PGM slices")
        .flag("accel", "rerun inference through the PJRT Pallas kernel");
    let args = match cli.parse(&std::env::args().skip(1).collect::<Vec<_>>()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    let dims = GridDims::new(
        args.get_usize("nx")?,
        args.get_usize("ny")?,
        args.get_usize("nz")?,
    );
    let k = args.get_usize("levels")?;
    let mut rng = Pcg32::seed_from_u64(args.get_u64("seed")?);

    // 1. Workload.
    let vol = retina::generate(dims, k, args.get_f64("noise")?, &mut rng);
    let noisy_err = retina::error_rate(&vol.clean, &vol.noisy);
    println!(
        "volume {}x{}x{} (k={k}), noisy error rate {:.3}",
        dims.nx, dims.ny, dims.nz, noisy_err
    );
    let mut mrf = retina::build_mrf(&vol, 0.8);

    // 2. Proxy ground-truth statistics via the sync machinery.
    let proxy = retina::smoothed_proxy(&vol, 1);
    let targets = target_stats(dims, &proxy);
    println!("target axis stats: [{:.3} {:.3} {:.3}]", targets[0], targets[1], targets[2]);

    // 3. Simultaneous learning + inference.
    let sdt = Sdt::new();
    sdt.set(LAMBDA_KEY, [1.0f64; 3]);
    sdt.set(TARGET_KEY, targets);
    let n = mrf.graph.num_vertices();
    let sched = SplashScheduler::new(n, |v| mrf.graph.neighbors(v), 32, args.get_usize("workers")?);
    for v in 0..n as u32 {
        sched.add_task(Task::with_priority(v, 1.0));
    }
    let mut upd = BpUpdate::new(k, 1e-4, Arc::new(Vec::new()));
    upd.learn_stats = true;
    upd.damping = 0.1;
    let sync = learning_sync(
        0.8,
        Some(Duration::from_millis(args.get_u64("sync-ms")?)),
    );
    let timer = Timer::start();
    let report = Program::new()
        .update_fn(&upd)
        .sync(sync)
        .workers(args.get_usize("workers")?)
        .model(ConsistencyModel::Edge)
        .max_updates(4_000_000)
        .run(&mut mrf.graph, &sched, &sdt);
    let lambda = sdt.get::<[f64; 3]>(LAMBDA_KEY).unwrap();
    println!(
        "learning+inference: {} updates, {} gradient steps, {:.2}s, learned lambda [{:.3} {:.3} {:.3}]",
        report.updates,
        sdt.get_or::<u64>(STEPS_KEY, 0),
        timer.elapsed_secs(),
        lambda[0],
        lambda[1],
        lambda[2]
    );
    print!("{}", run_summary(&report));

    // 4. Read out denoised levels (MAP per voxel) + metrics + images.
    let argmax = |b: &[f32]| -> u32 {
        b.iter().enumerate().max_by(|a, c| a.1.partial_cmp(c.1).unwrap()).unwrap().0 as u32
    };
    let denoised: Vec<u32> =
        (0..n as u32).map(|v| argmax(&mrf.graph.vertex_data(v).belief)).collect();
    let err = retina::error_rate(&vol.clean, &denoised);
    let to_f = |levels: &[u32]| -> Vec<f32> {
        levels.iter().map(|&l| l as f32 / (k - 1) as f32).collect()
    };
    let clean_f = to_f(&vol.clean);
    let psnr_noisy = psnr(&clean_f, &to_f(&vol.noisy), 1.0);
    let psnr_denoised = psnr(&clean_f, &to_f(&denoised), 1.0);
    println!(
        "error rate: noisy {noisy_err:.3} -> denoised {err:.3}; PSNR {psnr_noisy:.2} dB -> {psnr_denoised:.2} dB"
    );
    assert!(err < noisy_err, "denoising must improve the error rate");

    let out_dir = args.get("out-dir").to_string();
    let z = dims.nz / 2;
    let slice = |levels: &[u32]| -> Vec<f32> {
        (0..dims.ny * dims.nx)
            .map(|i| {
                let (x, y) = (i % dims.nx, i / dims.nx);
                levels[dims.index(x, y, z) as usize] as f32 / (k - 1) as f32
            })
            .collect()
    };
    write_pgm(Path::new(&out_dir).join("fig4d_noisy.pgm").as_path(), &slice(&vol.noisy), dims.nx, dims.ny)?;
    write_pgm(Path::new(&out_dir).join("fig4e_denoised.pgm").as_path(), &slice(&denoised), dims.nx, dims.ny)?;
    println!("wrote {out_dir}/fig4d_noisy.pgm and {out_dir}/fig4e_denoised.pgm");

    // 5. Optional: PJRT-accelerated inference cross-check.
    if args.get_flag("accel") {
        use graphlab::runtime::AccelGridBp;
        let dir = graphlab::runtime::default_artifact_dir();
        let mut accel_mrf = retina::build_mrf(&vol, 0.8);
        let mut accel = AccelGridBp::open(&dir, 256, k)?;
        let timer = Timer::start();
        let (sweeps, residual) = accel.run(&mut accel_mrf, lambda, 250, 1e-4)?;
        println!(
            "accel (PJRT {}): {} Jacobi sweeps to residual {:.2e} in {:.2}s",
            accel.platform(),
            sweeps,
            residual,
            timer.elapsed_secs()
        );
        let accel_denoised: Vec<u32> =
            (0..n as u32).map(|v| argmax(&accel_mrf.graph.vertex_data(v).belief)).collect();
        let agree = denoised
            .iter()
            .zip(&accel_denoised)
            .filter(|(a, b)| a == b)
            .count() as f64
            / denoised.len() as f64;
        // NOTE: the engine's beliefs converged while λ was still moving
        // (simultaneous learning), the accel pass uses the final λ only —
        // so agreement is high but not exact. The strict fixed-λ
        // equivalence check lives in rust/tests/runtime_pjrt.rs.
        println!("accel/engine denoised agreement: {:.1}%", agree * 100.0);
        assert!(agree > 0.8, "accelerated path must agree with the engine");
    }

    println!("denoise pipeline OK");
    Ok(())
}
