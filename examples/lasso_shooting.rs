//! Parallel Lasso via the Shooting algorithm (paper §4.4): automatic
//! parallelization under full consistency, plus the relaxed
//! vertex-consistency run the paper found to converge "with only 0.5%
//! higher loss".
//!
//! Run: `cargo run --release --example lasso_shooting -- [--dense]`

use graphlab::apps::lasso::{LassoProblem, ShootingUpdate};
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::finance::{self, FinanceConfig};
use graphlab::engine::Program;
use graphlab::scheduler::{FifoScheduler, Scheduler, Task};
use graphlab::sdt::Sdt;
use graphlab::util::{Cli, Pcg32, Timer};

fn run(
    p: &mut LassoProblem,
    lambda: f32,
    model: ConsistencyModel,
    workers: usize,
) -> (u64, f64) {
    let n = p.graph.num_vertices();
    let sched = FifoScheduler::new(n);
    for v in 0..p.num_weights as u32 {
        sched.add_task(Task::new(v));
    }
    let sdt = Sdt::new();
    let upd = ShootingUpdate::new(lambda);
    let timer = Timer::start();
    let report = Program::new()
        .update_fn(&upd)
        .workers(workers)
        .model(model)
        .max_updates(20_000_000)
        .run(&mut p.graph, &sched, &sdt);
    (report.updates, timer.elapsed_secs())
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("lasso_shooting", "Shooting-algorithm Lasso under full vs vertex consistency")
        .opt("scale", "0.2", "dataset scale")
        .opt("lambda", "2.0", "L1 strength")
        .opt("workers", "4", "worker threads")
        .opt("seed", "17", "rng seed")
        .flag("dense", "use the denser (common-words-kept) variant");
    let args = cli.parse(&std::env::args().skip(1).collect::<Vec<_>>()).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let scale = args.get_f64("scale")?;
    let cfg = if args.get_flag("dense") {
        FinanceConfig::denser(scale)
    } else {
        FinanceConfig::sparser(scale)
    };
    let lambda = args.get_f64("lambda")? as f32;
    let workers = args.get_usize("workers")?;

    let gen = || {
        let mut rng = Pcg32::seed_from_u64(args.get_u64("seed").unwrap());
        finance::generate(&cfg, &mut rng).0
    };
    let probe = gen();
    println!(
        "dataset: {} features, {} documents, {} non-zeros ({})",
        probe.num_weights,
        probe.num_obs,
        probe.graph.num_edges() / 2,
        if args.get_flag("dense") { "denser" } else { "sparser" }
    );

    let mut full = gen();
    let (updates_full, secs_full) = run(&mut full, lambda, ConsistencyModel::Full, workers);
    let loss_full = full.loss(lambda);
    let nnz_full = full.weights().iter().filter(|w| w.abs() > 1e-6).count();
    println!(
        "full consistency:   {updates_full:>9} updates, {secs_full:>6.2}s, loss {loss_full:.4}, nnz {nnz_full}"
    );

    let mut vtx = gen();
    let (updates_vtx, secs_vtx) = run(&mut vtx, lambda, ConsistencyModel::Vertex, workers);
    let loss_vtx = vtx.loss(lambda);
    let nnz_vtx = vtx.weights().iter().filter(|w| w.abs() > 1e-6).count();
    println!(
        "vertex consistency: {updates_vtx:>9} updates, {secs_vtx:>6.2}s, loss {loss_vtx:.4}, nnz {nnz_vtx}"
    );

    let rel = (loss_vtx - loss_full) / loss_full.max(1e-12);
    println!("relaxed-consistency loss delta: {:+.3}% (paper: ~+0.5%)", rel * 100.0);
    assert!(rel.abs() < 0.05, "vertex consistency must land near the full-consistency loss");
    println!("lasso_shooting OK");
    Ok(())
}
