//! Quickstart: the GraphLab programming model in ~80 lines.
//!
//! We solve a toy "heat diffusion" fixed point on a 2-D grid: every vertex
//! repeatedly averages with its neighbors until nothing moves. The program
//! shows the five GraphLab ingredients (paper §3.6): the data graph, an
//! update function, a sync (global average), a consistency model, and a
//! scheduler.
//!
//! Run: `cargo run --release --example quickstart`

use graphlab::consistency::{ConsistencyModel, Scope};
use graphlab::engine::{Program, UpdateContext, UpdateFn};
use graphlab::graph::GraphBuilder;
use graphlab::scheduler::{MultiQueueFifo, Scheduler, Task};
use graphlab::sdt::{Sdt, SyncOpBuilder};

/// Update function: move half-way toward the neighborhood mean; reschedule
/// the neighborhood while we keep moving.
struct Diffuse {
    tolerance: f64,
}

impl UpdateFn<f64, ()> for Diffuse {
    fn update(&self, scope: &mut Scope<'_, f64, ()>, ctx: &mut UpdateContext<'_>) {
        let nbrs = scope.neighbors();
        if nbrs.is_empty() {
            return;
        }
        let mean: f64 = nbrs.iter().map(|&u| *scope.neighbor(u)).sum::<f64>() / nbrs.len() as f64;
        let old = *scope.vertex();
        let new = 0.5 * old + 0.5 * mean;
        *scope.vertex_mut() = new;
        if (new - old).abs() > self.tolerance {
            for &u in nbrs {
                ctx.add_task(u, (new - old).abs());
            }
        }
    }
}

fn main() {
    // 1. Data graph: a 32x32 grid, hot corner, cold everywhere else.
    let side = 32u32;
    let mut b: GraphBuilder<f64, ()> = GraphBuilder::new();
    for i in 0..side * side {
        b.add_vertex(if i == 0 { 100.0 } else { 0.0 });
    }
    for y in 0..side {
        for x in 0..side {
            let v = y * side + x;
            if x + 1 < side {
                b.add_undirected(v, v + 1, (), ());
            }
            if y + 1 < side {
                b.add_undirected(v, v + side, (), ());
            }
        }
    }
    let mut graph = b.build();
    let n = graph.num_vertices();

    // 2. Scheduler: relaxed FIFO, seeded with every vertex.
    let sched = MultiQueueFifo::new(n, 4);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }

    // 3. Sync: track the global mean temperature in the shared data table.
    let sdt = Sdt::new();
    let mean_op = SyncOpBuilder::<f64, (f64, u64)>::new("mean", (0.0, 0)).build_with_merge(
        |(s, c), v| (s + *v, c + 1),
        |(s1, c1), (s2, c2)| (s1 + s2, c1 + c2),
        |(s, c), sdt| sdt.set("mean", s / c.max(1) as f64),
    );

    // 4+5. Consistency model + engine: the Program bundles the update
    // function, the sync, and the run configuration; the threaded back-end
    // manages its own lock table.
    let diffuse = Diffuse { tolerance: 1e-6 };
    let report = Program::new()
        .update_fn(&diffuse)
        .sync(mean_op)
        .workers(4)
        .model(ConsistencyModel::Edge)
        .run(&mut graph, &sched, &sdt);

    println!(
        "converged: {} updates on {} workers in {:.3}s ({:.0} updates/s)",
        report.updates,
        report.per_worker.len(),
        report.wall_secs,
        report.updates_per_sec()
    );
    println!("global mean temperature (sync): {:.4}", sdt.get::<f64>("mean").unwrap());
    let corner = *graph.vertex_data(0);
    let center = *graph.vertex_data(side * side / 2 + side / 2);
    println!("corner={corner:.3} center={center:.3}");
}
