"""Layer-2 model tests: the fused chain-BP sweeps and AOT entry points."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def chain_bp_reference(potentials, psi, sweeps):
    """Straightforward numpy chain BP (Jacobi sweeps)."""
    pot = np.asarray(potentials, dtype=np.float64)
    p = np.asarray(psi, dtype=np.float64)
    n, k = pot.shape
    fwd = np.ones((n - 1, k)) / k
    bwd = np.ones((n - 1, k)) / k

    def norm(x):
        return x / np.maximum(x.sum(axis=-1, keepdims=True), 1e-30)

    for _ in range(sweeps):
        in_l = np.concatenate([np.ones((1, k)), fwd], axis=0)
        in_r = np.concatenate([bwd, np.ones((1, k))], axis=0)
        belief = norm(pot * in_l * in_r)
        cav_f = norm(belief[:-1] / np.maximum(in_r[:-1], 1e-30))
        cav_b = norm(belief[1:] / np.maximum(in_l[1:], 1e-30))
        fwd = norm(cav_f @ p)
        bwd = norm(cav_b @ p)
    in_l = np.concatenate([np.ones((1, k)), fwd], axis=0)
    in_r = np.concatenate([bwd, np.ones((1, k))], axis=0)
    return fwd, bwd, norm(pot * in_l * in_r)


def exact_chain_marginals(potentials, psi):
    """Brute-force marginals of a tiny chain MRF."""
    pot = np.asarray(potentials, dtype=np.float64)
    p = np.asarray(psi, dtype=np.float64)
    n, k = pot.shape
    marg = np.zeros((n, k))
    import itertools

    for assign in itertools.product(range(k), repeat=n):
        w = 1.0
        for v, x in enumerate(assign):
            w *= pot[v, x]
        for v in range(n - 1):
            w *= p[assign[v], assign[v + 1]]
        for v, x in enumerate(assign):
            marg[v, x] += w
    return marg / marg.sum(axis=1, keepdims=True)


def test_chain_sweeps_match_numpy_reference():
    rng = np.random.default_rng(0)
    n, k, sweeps = 8, 5, 4
    pot = jnp.asarray(rng.uniform(0.2, 1.0, (n, k)).astype(np.float32))
    psi_raw = rng.uniform(0.2, 1.0, (k, k))
    psi = jnp.asarray(((psi_raw + psi_raw.T) / 2).astype(np.float32))
    fwd0 = jnp.full((n - 1, k), 1.0 / k)
    bwd0 = jnp.full((n - 1, k), 1.0 / k)
    fwd, bwd, belief = model.bp_grid_sweeps(pot, psi, fwd0, bwd0, sweeps)
    fwd_r, bwd_r, belief_r = chain_bp_reference(pot, psi, sweeps)
    np.testing.assert_allclose(fwd, fwd_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(bwd, bwd_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(belief, belief_r, rtol=1e-4, atol=1e-5)


def test_chain_sweeps_converge_to_exact_marginals():
    # BP on a chain (tree) is exact once converged
    rng = np.random.default_rng(1)
    n, k = 5, 3
    pot = jnp.asarray(rng.uniform(0.3, 1.0, (n, k)).astype(np.float32))
    psi_raw = rng.uniform(0.3, 1.0, (k, k))
    psi = jnp.asarray(((psi_raw + psi_raw.T) / 2).astype(np.float32))
    fwd0 = jnp.full((n - 1, k), 1.0 / k)
    bwd0 = jnp.full((n - 1, k), 1.0 / k)
    _, _, belief = model.bp_grid_sweeps(pot, psi, fwd0, bwd0, 2 * n)
    exact = exact_chain_marginals(pot, psi)
    np.testing.assert_allclose(belief, exact, rtol=5e-3, atol=1e-4)


def test_entry_points_cover_all_kernels():
    names = [name for name, _, _ in aot.entry_points()]
    assert any(n.startswith("bp_batch") for n in names)
    assert any(n.startswith("gabp_batch") for n in names)
    assert any(n.startswith("coem_batch") for n in names)
    assert any(n.startswith("bp_chain") for n in names)


@pytest.mark.parametrize("name,fn,in_specs", aot.entry_points())
def test_entry_points_lower_to_hlo_text(name, fn, in_specs):
    import jax

    lowered = jax.jit(fn).lower(*in_specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), f"{name}: not HLO text"
    assert "ENTRY" in text
