"""Pallas kernels vs pure-jnp oracles — the build-time correctness gate.

hypothesis sweeps shapes and values; every kernel must match its ref.py
oracle to float32 tolerance across the sweep.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bp_message_batch, coem_belief_batch, gabp_message_batch
from compile.kernels.ref import (
    bp_message_batch_ref,
    coem_belief_batch_ref,
    gabp_message_batch_ref,
)

SETTINGS = dict(max_examples=25, deadline=None)


def rng_array(seed, shape, lo=0.0, hi=1.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(lo, hi, size=shape).astype(np.float32))


# ---------------------------------------------------------------- BP ------


@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 4),
    block_b=st.sampled_from([8, 32, 128]),
    k=st.integers(2, 9),
    seed=st.integers(0, 2**31),
)
def test_bp_matches_ref(blocks, block_b, k, seed):
    b = blocks * block_b
    cavity = rng_array(seed, (b, k), 0.01, 1.0)
    psi = rng_array(seed + 1, (k, k), 0.05, 1.0)
    old = rng_array(seed + 2, (b, k), 0.01, 1.0)
    msg, res = bp_message_batch(cavity, psi, old, block_b=block_b)
    msg_ref, res_ref = bp_message_batch_ref(cavity, psi, old)
    np.testing.assert_allclose(msg, msg_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(res, res_ref, rtol=1e-4, atol=1e-5)


def test_bp_messages_are_normalized():
    cavity = rng_array(0, (256, 5), 0.01, 1.0)
    psi = rng_array(1, (5, 5), 0.05, 1.0)
    old = rng_array(2, (256, 5), 0.01, 1.0)
    msg, _ = bp_message_batch(cavity, psi, old)
    np.testing.assert_allclose(jnp.sum(msg, axis=1), np.ones(256), rtol=1e-5)


def test_bp_zero_residual_at_fixed_point():
    cavity = rng_array(3, (128, 4), 0.01, 1.0)
    psi = rng_array(4, (4, 4), 0.05, 1.0)
    msg, _ = bp_message_batch(cavity, psi, jnp.zeros((128, 4)))
    _, res = bp_message_batch(cavity, psi, msg)
    np.testing.assert_allclose(res, np.zeros(128), atol=1e-6)


def test_bp_rejects_ragged_batch():
    with pytest.raises(AssertionError):
        bp_message_batch(
            jnp.ones((100, 4)), jnp.ones((4, 4)), jnp.ones((100, 4)), block_b=128
        )


# -------------------------------------------------------------- GaBP ------


@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 3),
    block_b=st.sampled_from([64, 512]),
    seed=st.integers(0, 2**31),
)
def test_gabp_matches_ref(blocks, block_b, seed):
    b = blocks * block_b
    p_cav = rng_array(seed, (b,), 0.5, 5.0)
    h_cav = rng_array(seed + 1, (b,), -3.0, 3.0)
    a = rng_array(seed + 2, (b,), -1.0, 1.0)
    p_out, h_out = gabp_message_batch(p_cav, h_cav, a, block_b=block_b)
    p_ref, h_ref = gabp_message_batch_ref(p_cav, h_cav, a)
    np.testing.assert_allclose(p_out, p_ref, rtol=1e-6)
    np.testing.assert_allclose(h_out, h_ref, rtol=1e-6)


def test_gabp_message_signs():
    # outbound precision is always negative for nonzero coupling & positive cavity
    p_cav = jnp.full((512,), 2.0)
    h_cav = jnp.full((512,), 1.0)
    a = jnp.full((512,), 0.5)
    p_out, h_out = gabp_message_batch(p_cav, h_cav, a)
    assert np.all(np.asarray(p_out) < 0)
    np.testing.assert_allclose(p_out, np.full(512, -0.125), rtol=1e-6)
    np.testing.assert_allclose(h_out, np.full(512, -0.25), rtol=1e-6)


# -------------------------------------------------------------- CoEM ------


@settings(**SETTINGS)
@given(
    block_b=st.sampled_from([8, 128]),
    d=st.integers(1, 16),
    k=st.integers(2, 6),
    seed=st.integers(0, 2**31),
)
def test_coem_matches_ref(block_b, d, k, seed):
    b = block_b
    nb = rng_array(seed, (b, d, k), 0.0, 1.0)
    w = rng_array(seed + 1, (b, d), 0.0, 3.0)
    out = coem_belief_batch(nb, w, block_b=block_b)
    out_ref = coem_belief_batch_ref(nb, w)
    np.testing.assert_allclose(out, out_ref, rtol=1e-5, atol=1e-6)


def test_coem_padding_is_neutral():
    # appending zero-weight neighbors must not change the result
    nb = rng_array(7, (128, 4, 3), 0.0, 1.0)
    w = rng_array(8, (128, 4), 0.1, 2.0)
    out = coem_belief_batch(nb, w)
    nb_pad = jnp.concatenate([nb, rng_array(9, (128, 4, 3))], axis=1)
    w_pad = jnp.concatenate([w, jnp.zeros((128, 4))], axis=1)
    out_pad = coem_belief_batch(nb_pad, w_pad)
    np.testing.assert_allclose(out, out_pad, rtol=1e-5, atol=1e-6)


def test_coem_normalized_inputs_stay_normalized():
    nb = rng_array(10, (128, 6, 4), 0.01, 1.0)
    nb = nb / jnp.sum(nb, axis=2, keepdims=True)
    w = rng_array(11, (128, 6), 0.1, 1.0)
    out = coem_belief_batch(nb, w)
    np.testing.assert_allclose(jnp.sum(out, axis=1), np.ones(128), rtol=1e-5)
