"""Layer-2 JAX compute graphs wrapping the Layer-1 Pallas kernels.

These are the functions that get AOT-lowered (by ``aot.py``) to the HLO
artifacts the rust coordinator executes via PJRT — python never runs on the
request path. Each entry point keeps the kernel call inside the jitted
function so the Pallas program lowers into the same HLO module.

Entry points (all f32):

  * ``bp_batch``      — one batched BP message step (cavity, psi, old) ->
                        (msg, residual).
  * ``bp_grid_sweeps``— fused multi-sweep grid BP: ``lax.scan`` over S
                        Jacobi sweeps of a 1-D chain decomposition (used by
                        the denoise pipeline's accelerated inner loop).
                        Scan keeps the artifact small (no unrolling) and
                        lets XLA pipeline the sweeps.
  * ``gabp_batch``    — batched GaBP edge messages.
  * ``coem_batch``    — batched CoEM belief averaging.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import bp_message_batch, coem_belief_batch, gabp_message_batch


def bp_batch(cavity, psi, old_msg):
    """Single batched BP message step (see kernels.bp_msgs)."""
    return bp_message_batch(cavity, psi, old_msg)


def gabp_batch(p_cav, h_cav, a):
    """Batched GaBP edge messages (see kernels.gabp)."""
    return gabp_message_batch(p_cav, h_cav, a)


def coem_batch(nb, w):
    """Batched CoEM belief averaging (see kernels.coem)."""
    return coem_belief_batch(nb, w)


def bp_grid_sweeps(potentials, psi, msgs_fwd, msgs_bwd, num_sweeps: int):
    """Fused multi-sweep BP along a chain of length N with K levels.

    The 3-D grid BP decomposes into axis-aligned chains; the rust
    coordinator extracts chains (one batch row per chain position is
    overkill — entire chains are contiguous), runs S sweeps on-device, and
    scatters messages back.

    Args:
      potentials: f32[N, K] node potentials along the chain.
      psi:        f32[K, K] symmetric edge potential for this axis.
      msgs_fwd:   f32[N-1, K] messages i -> i+1.
      msgs_bwd:   f32[N-1, K] messages i+1 -> i.
      num_sweeps: static sweep count.

    Returns:
      (msgs_fwd', msgs_bwd', beliefs f32[N, K]).
    """
    n, k = potentials.shape

    def normalize(x):
        return x / jnp.maximum(jnp.sum(x, axis=-1, keepdims=True), 1e-30)

    def sweep(carry, _):
        fwd, bwd = carry
        # beliefs use current messages: inbound fwd (from left) + bwd (right)
        inbound_left = jnp.concatenate([jnp.ones((1, k)), fwd], axis=0)
        inbound_right = jnp.concatenate([bwd, jnp.ones((1, k))], axis=0)
        belief = normalize(potentials * inbound_left * inbound_right)
        # cavity for fwd messages: belief[i] / inbound from the right at i
        cav_f = normalize(belief[:-1] / jnp.maximum(inbound_right[:-1], 1e-30))
        cav_b = normalize(belief[1:] / jnp.maximum(inbound_left[1:], 1e-30))
        new_fwd, _ = bp_message_batch(cav_f, psi, fwd, block_b=_chain_block(n - 1))
        new_bwd, _ = bp_message_batch(cav_b, psi, bwd, block_b=_chain_block(n - 1))
        return (new_fwd, new_bwd), None

    (fwd, bwd), _ = lax.scan(sweep, (msgs_fwd, msgs_bwd), None, length=num_sweeps)
    inbound_left = jnp.concatenate([jnp.ones((1, k)), fwd], axis=0)
    inbound_right = jnp.concatenate([bwd, jnp.ones((1, k))], axis=0)
    belief = normalize(potentials * inbound_left * inbound_right)
    return fwd, bwd, belief


def _chain_block(rows: int) -> int:
    """Largest power-of-two block that divides the row count (<=128)."""
    b = 1
    while b < 128 and rows % (b * 2) == 0:
        b *= 2
    return b
