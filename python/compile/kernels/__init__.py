"""Layer-1 Pallas kernels (build-time only; interpret=True on CPU PJRT)."""

from .bp_msgs import bp_message_batch  # noqa: F401
from .coem import coem_belief_batch  # noqa: F401
from .gabp import gabp_message_batch  # noqa: F401
