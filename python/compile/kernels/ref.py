"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package is validated against these references by
``python/tests`` (exact math, no blocking, no Pallas) before the AOT
artifacts are built.
"""

import jax.numpy as jnp


def bp_message_batch_ref(cavity, psi, old_msg):
    """Reference for kernels.bp_msgs.bp_message_batch."""
    raw = cavity @ psi
    total = jnp.sum(raw, axis=1, keepdims=True)
    msg = raw / jnp.maximum(total, 1e-30)
    res = jnp.sum(jnp.abs(msg - old_msg), axis=1)
    return msg, res


def gabp_message_batch_ref(p_cav, h_cav, a):
    """Reference for kernels.gabp.gabp_message_batch."""
    keep = jnp.abs(p_cav) > 1e-300
    denom = jnp.where(keep, p_cav, 1.0)
    p_out = jnp.where(keep, -(a * a) / denom, 0.0)
    h_out = jnp.where(keep, -(a * h_cav) / denom, 0.0)
    return p_out, h_out


def coem_belief_batch_ref(nb, w):
    """Reference for kernels.coem.coem_belief_batch."""
    acc = jnp.einsum("bdk,bd->bk", nb, w)
    total = jnp.sum(w, axis=1, keepdims=True)
    return acc / jnp.maximum(total, 1e-30)
