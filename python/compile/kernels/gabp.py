"""Layer-1 Pallas kernel: batched GaBP message updates.

One batch row = one directed edge i->j of the Gaussian BP solver
(apps/gabp.rs). Inputs are the cavity precision / precision-mean and the
coupling A_ij; outputs the outbound message pair:

    P_out[b] = -a[b]^2 / P_cav[b]
    h_out[b] = -a[b] * h_cav[b] / P_cav[b]

Purely elementwise (VPU work, no MXU); the value of offloading is batching
thousands of scalar edge updates into one device launch. Blocked along the
batch so arbitrarily large batches stream through VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 512


def _gabp_kernel(p_cav_ref, h_cav_ref, a_ref, p_out_ref, h_out_ref):
    p_cav = p_cav_ref[...]
    h_cav = h_cav_ref[...]
    a = a_ref[...]
    denom = jnp.where(jnp.abs(p_cav) > 1e-300, p_cav, 1.0)
    p_out = -(a * a) / denom
    h_out = -(a * h_cav) / denom
    keep = jnp.abs(p_cav) > 1e-300
    p_out_ref[...] = jnp.where(keep, p_out, 0.0)
    h_out_ref[...] = jnp.where(keep, h_out, 0.0)


@functools.partial(jax.jit, static_argnames=("block_b",))
def gabp_message_batch(p_cav, h_cav, a, *, block_b=DEFAULT_BLOCK_B):
    """Batched GaBP messages.

    Args:
      p_cav: f32[B] cavity precisions (P_i - P_{j->i}).
      h_cav: f32[B] cavity precision-means.
      a:     f32[B] couplings A_ij.

    Returns:
      (P_out f32[B], h_out f32[B]).
    """
    (b,) = p_cav.shape
    assert h_cav.shape == (b,) and a.shape == (b,)
    assert b % block_b == 0, f"B={b} must be a multiple of block_b={block_b}"
    grid = (b // block_b,)
    spec = pl.BlockSpec((block_b,), lambda i: (i,))
    return pl.pallas_call(
        _gabp_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(p_cav, h_cav, a)
