"""Layer-1 Pallas kernel: batched BP message computation.

The GraphLab coordinator (Layer 3, rust) drains a consistency-safe batch of
BP tasks and executes all of their outbound-message computations as one
tensor program. Per batch row b (one directed edge v->t):

    raw[b, j]   = sum_i psi[b // 1, i, j] * cavity[b, i]     (MXU contraction)
    msg[b, j]   = raw[b, j] / sum_j raw[b, j]                (normalize)
    res[b]      = sum_j |msg[b, j] - old_msg[b, j]|          (L1 residual)

The potential is shared across the batch (grid MRFs have one Laplace psi per
axis; the L2 wrapper selects the axis before the call), so the contraction
is ``[B, K] @ [K, K]`` — a clean systolic-array (MXU) shape once K is padded
to the lane width.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  * the batch dimension is tiled by BlockSpec into VMEM-resident blocks
    (``block_b`` rows at a time); psi is small and replicated per block;
  * ``interpret=True`` everywhere in this repo — the CPU PJRT plugin cannot
    execute Mosaic custom-calls; real-TPU efficiency is *estimated* from the
    block geometry (see EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM block. 128 rows x K(<=16) f32 is tiny; the figure is chosen
# so psi + 3 row-blocks stay far below the ~16 MiB VMEM budget while keeping
# the MXU contraction shape (128, K) x (K, K).
DEFAULT_BLOCK_B = 128


def _bp_kernel(cavity_ref, psi_ref, old_ref, msg_ref, res_ref):
    """One block: rows of cavity/old, full psi."""
    cavity = cavity_ref[...]          # [bm, K]
    psi = psi_ref[...]                # [K, K]
    old = old_ref[...]                # [bm, K]
    raw = jnp.dot(cavity, psi, preferred_element_type=jnp.float32)
    total = jnp.sum(raw, axis=1, keepdims=True)
    msg = raw / jnp.maximum(total, 1e-30)
    msg_ref[...] = msg
    res_ref[...] = jnp.sum(jnp.abs(msg - old), axis=1)


@functools.partial(jax.jit, static_argnames=("block_b",))
def bp_message_batch(cavity, psi, old_msg, *, block_b=DEFAULT_BLOCK_B):
    """Batched BP message update.

    Args:
      cavity:  f32[B, K] cavity distributions (belief / inbound message).
      psi:     f32[K, K] edge potential, msg[j] = sum_i psi[i, j] cavity[i].
      old_msg: f32[B, K] previous messages (for the residuals).
      block_b: rows per Pallas block (B must be a multiple).

    Returns:
      (msg f32[B, K], residual f32[B]).
    """
    b, k = cavity.shape
    assert psi.shape == (k, k), psi.shape
    assert old_msg.shape == (b, k)
    assert b % block_b == 0, f"B={b} must be a multiple of block_b={block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _bp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(cavity, psi, old_msg)
