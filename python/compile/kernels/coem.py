"""Layer-1 Pallas kernel: batched CoEM belief averaging.

One batch row = one CoEM vertex whose neighborhood was gathered (by L2/L3)
into a padded dense block:

    nb[b, d, k]   belief of the d-th neighbor of vertex b (zero-padded)
    w[b, d]       edge weight (0 for padding)

    out[b, k] = sum_d w[b, d] * nb[b, d, k] / max(sum_d w[b, d], eps)

The weighted reduction over d is a small matvec per row; the padded-degree
layout turns the paper's irregular fine-grained updates into a dense,
vectorizable block — the TPU restatement of the CoEM hot loop.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _coem_kernel(nb_ref, w_ref, out_ref):
    nb = nb_ref[...]      # [bm, D, K]
    w = w_ref[...]        # [bm, D]
    acc = jnp.einsum("bdk,bd->bk", nb, w)
    total = jnp.sum(w, axis=1, keepdims=True)
    out_ref[...] = acc / jnp.maximum(total, 1e-30)


@functools.partial(jax.jit, static_argnames=("block_b",))
def coem_belief_batch(nb, w, *, block_b=DEFAULT_BLOCK_B):
    """Batched CoEM belief update.

    Args:
      nb: f32[B, D, K] padded neighbor beliefs.
      w:  f32[B, D] edge weights (0 = padding).

    Returns:
      f32[B, K] new beliefs.
    """
    b, d, k = nb.shape
    assert w.shape == (b, d)
    assert b % block_b == 0, f"B={b} must be a multiple of block_b={block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _coem_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,
    )(nb, w)
