"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO **text** artifacts for the
rust PJRT runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Artifacts land in ``artifacts/`` together with ``manifest.tsv``:

    name <TAB> path <TAB> in:<shape;...> <TAB> out:<shape;...>

(shape = dtype:d0xd1x...). TSV keeps the rust-side parser dependency-free.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default artifact shapes. The rust runtime pads batches to these sizes;
# several B variants let the batcher trade padding waste against launches.
BP_K = 5
BP_BATCHES = (256, 1024)
GABP_BATCHES = (1024, 4096)
COEM_DEGREE = 32
COEM_K = 4
COEM_BATCHES = (256,)
CHAIN_N = 64
CHAIN_SWEEPS = 4


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _fmt(specs):
    return ";".join("f32:" + "x".join(str(d) for d in s.shape) for s in specs)


def entry_points():
    """(name, fn, input ShapeDtypeStructs) for every artifact."""
    out = []
    for b in BP_BATCHES:
        out.append(
            (
                f"bp_batch_b{b}_k{BP_K}",
                model.bp_batch,
                [_spec(b, BP_K), _spec(BP_K, BP_K), _spec(b, BP_K)],
            )
        )
    for b in GABP_BATCHES:
        out.append(
            (f"gabp_batch_b{b}", model.gabp_batch, [_spec(b), _spec(b), _spec(b)])
        )
    for b in COEM_BATCHES:
        out.append(
            (
                f"coem_batch_b{b}_d{COEM_DEGREE}_k{COEM_K}",
                model.coem_batch,
                [_spec(b, COEM_DEGREE, COEM_K), _spec(b, COEM_DEGREE)],
            )
        )
    out.append(
        (
            f"bp_chain_n{CHAIN_N}_k{BP_K}_s{CHAIN_SWEEPS}",
            lambda pot, psi, f, bwd: model.bp_grid_sweeps(pot, psi, f, bwd, CHAIN_SWEEPS),
            [
                _spec(CHAIN_N, BP_K),
                _spec(BP_K, BP_K),
                _spec(CHAIN_N - 1, BP_K),
                _spec(CHAIN_N - 1, BP_K),
            ],
        )
    )
    return out


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_rows = []
    for name, fn, in_specs in entry_points():
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *in_specs)
        out_flat = jax.tree_util.tree_leaves(out_specs)
        manifest_rows.append(
            f"{name}\t{fname}\tin:{_fmt(in_specs)}\tout:{_fmt(out_flat)}"
        )
        print(f"  {name}: {len(text)} chars, out {_fmt(out_flat)}")
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_rows) + "\n")
    print(f"wrote {len(manifest_rows)} artifacts to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) ignored if --out-dir set")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out and not args.out_dir:
        out_dir = os.path.dirname(args.out)
    build(out_dir)


if __name__ == "__main__":
    main()
